"""Roofline summary: reads the dry-run artifacts and emits the per-cell
terms as CSV (and a markdown table to artifacts/roofline.md)."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def run():
    rows = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok" or "roofline" not in r:
            if r.get("status") == "skipped":
                emit(f"roofline/{r['cell']}", 0.0, "skipped-by-design")
            continue
        rl = r["roofline"]
        if not rl.get("flops"):
            # multi-pod cells are compile-proof only (no unrolled cost twin)
            emit(f"roofline/{r['cell']}", 0.0,
                 f"compile-proof,collGB={r['collectives']['total']/1e9:.2f}")
            continue
        emit(f"roofline/{r['cell']}", rl["step_time_s"] * 1e6,
             (f"bottleneck={rl['bottleneck']},mfu={rl['mfu_at_roofline']:.4f},"
              f"useful={rl['useful_flops_frac']:.3f}"))
        rows.append((r["cell"], rl))

    md = ["| cell | t_compute (s) | t_memory (s) | t_collective (s) | "
          "bottleneck | MFU@roofline | useful FLOPs |",
          "|---|---|---|---|---|---|---|"]
    for cell, rl in rows:
        md.append(
            f"| {cell} | {rl['t_compute_s']:.4g} | {rl['t_memory_s']:.4g} | "
            f"{rl['t_collective_s']:.4g} | {rl['bottleneck']} | "
            f"{rl['mfu_at_roofline']:.2%} | {rl['useful_flops_frac']:.2f} |")
    out = ART.parent / "roofline.md"
    out.write_text("\n".join(md) + "\n")


if __name__ == "__main__":
    run()
