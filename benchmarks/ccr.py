"""Paper Fig. 2C: LP classification accuracy vs problem size, 10% labels,
exact vs kNN vs VariationalDT under identical conditions."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.baselines import (build_knn_graph, exact_transition_matrix,
                                  knn_matvec)
from repro.core.label_prop import ccr, label_propagate, one_hot_labels
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import digit1_like

import os
FAST = os.environ.get("BENCH_FAST", "0") == "1"
SIZES = (500, 1500) if FAST else (250, 500, 1000, 1500)
ALPHA, ITERS = 0.01, 200 if FAST else 500


def run():
    data = digit1_like(n=max(SIZES))
    rng = np.random.RandomState(0)
    for n in SIZES:
        x = jnp.asarray(data.x[:n])
        labels = data.labels[:n]
        labeled = np.zeros(n, bool)
        labeled[rng.choice(n, max(n // 10, 2), replace=False)] = True
        y0 = one_hot_labels(labels, labeled, data.n_classes)

        vdt = VariationalDualTree.fit(x, max_blocks=4 * n)
        sig = jnp.asarray(vdt.sigma)
        yf = label_propagate(vdt.matvec, y0, ALPHA, ITERS)
        acc_v = ccr(yf, labels, ~labeled)
        emit(f"fig2c/ccr/vdt/n={n}", 0.0, f"ccr={acc_v:.4f}")

        g = build_knn_graph(x, 4, sig)
        yf = label_propagate(lambda y: knn_matvec(g, y), y0, ALPHA, ITERS)
        emit(f"fig2c/ccr/knn4/n={n}", 0.0,
             f"ccr={ccr(yf, labels, ~labeled):.4f}")

        p = exact_transition_matrix(x, sig)
        yf = label_propagate(lambda y: p @ y, y0, ALPHA, ITERS)
        emit(f"fig2c/ccr/exact/n={n}", 0.0,
             f"ccr={ccr(yf, labels, ~labeled):.4f}")


if __name__ == "__main__":
    run()
