"""Paper Fig. 2A: model construction time vs problem size N.

Compares exact (O(N^2)), kNN (blocked brute force + top_k), and
VariationalDT (O(N log N) tree + O(|B|) q-opt) builds on SecStr-like data,
the paper's first experiment (synthetic surrogate, DESIGN.md §8).

Times are reported WARM (jit caches primed by a same-shape build) — the
deployment regime, and the regime where the paper's serial-CPU comparison is
meaningful; the one-off XLA compile is reported separately as `cold`.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.baselines import build_knn_graph, exact_transition_matrix
from repro.core.sigma import sigma_init
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import secstr_like

FAST = os.environ.get("BENCH_FAST", "0") == "1"
SIZES_EXACT = (500, 1000, 2000, 4000)
SIZES_ALL = (500, 1000, 2000, 4000) if FAST else (500, 1000, 2000, 4000,
                                                  8000, 16000)


def _cold_warm(fn):
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    cold = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    warm = (time.perf_counter() - t0) * 1e6
    return cold, warm


def run():
    data = secstr_like(n=max(SIZES_ALL), d=315)
    for n in SIZES_ALL:
        x = data.x[:n]
        sig = float(sigma_init(jnp.asarray(x)))

        def build_vdt():
            v = VariationalDualTree.fit(x, sigma=sig, learn_sigma=False)
            return v.qstate.log_q

        cold, warm = _cold_warm(build_vdt)
        emit(f"fig2a/construct/vdt/n={n}", warm, f"cold_us={cold:.0f}")
        us_vdt = warm

        xj = jnp.asarray(x)
        cold, warm = _cold_warm(
            lambda: build_knn_graph(xj, 2, jnp.asarray(sig)).weights)
        emit(f"fig2a/construct/knn2/n={n}", warm,
             f"cold_us={cold:.0f},vdt_speedup={warm / max(us_vdt, 1):.2f}x")

        if n in SIZES_EXACT:
            cold, warm = _cold_warm(
                lambda: exact_transition_matrix(xj, jnp.asarray(sig)))
            emit(f"fig2a/construct/exact/n={n}", warm,
                 f"cold_us={cold:.0f},vdt_speedup={warm / max(us_vdt, 1):.2f}x")


if __name__ == "__main__":
    run()
