"""Serving-engine benchmark: per-policy closed-loop scenarios + CI gate data.

Scheduler-v2 companion of the PR-2 engine benchmark: one fitted VDT
(N=4096 full / N=256 tiny) is measured under four scenarios, each feeding a
namespaced section of ``BENCH_serving.json`` that the CI bench gate holds
to per-policy bounds in ``benchmarks/baselines.json``:

``uniform``          the original PR-2 measurement (``fifo`` section):
                     serial per-request loop vs the engine under K
                     closed-loop clients — throughput, latency, occupancy.
``bursty``           clients submit whole bursts separated by idle gaps;
                     the rate-adaptive linger must coalesce each burst into
                     few dispatches (``bursty`` section: occupancy, p95).
``mixed-priority``   a backlogged population of low-priority closed-loop
                     clients plus one latency-sensitive high-priority
                     client, run under ``policy="fifo"`` then
                     ``policy="priority"`` at equal offered load.  The
                     gate bound: high-priority p95 under the priority
                     policy must undercut FIFO by >= 2x
                     (``mixed_priority.hi_p95_improvement``).
``deadline-heavy``   background deadline-less traffic plus a client whose
                     requests carry tight deadlines, under ``fifo`` vs
                     ``edf``.  EDF must actually meet deadlines:
                     ``edf.deadline_miss_rate`` is gated with a MAX bound.
``multi-tenant``     three tenants (gold:silver:bronze weights 3:1:1) share
                     ONE fitted tree behind an ``EngineFleet``; per-tenant
                     closed-loop clients keep every tenant backlogged and
                     the deficit-round-robin scheduler must split the
                     measured window's throughput by weight.  The gate
                     bounds: the window's worst relative share deviation
                     (``fleet.fair_share_err``, MAX) plus per-tenant p95
                     caps — fair sharing must not come at the price of an
                     unbounded tail for any tenant.
``preempt``          head-of-line blocking behind IN-FLIGHT work: bulk
                     clients keep long scans (``BULK_ITERS`` iterations) on
                     the device while tight-deadline arrivals land mid-scan,
                     under ``edf`` monolithic vs ``edf`` +
                     ``segment_iters``.  Preemptible dispatch must serve an
                     urgent arrival at the next segment boundary instead of
                     after the whole scan: ``preempt.p95_preempt_ms`` is
                     gated with a MAX bound (the monolithic figures are
                     recorded for comparison, not gated).

``sharded``          the multi-device engine A/B: the SAME closed-loop
                     load served by a ``ShardedPropagateEngine`` on a
                     1-device mesh vs the full visible mesh.  Run under
                     ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
                     in CI; the gated ``sharded.scaling_floor`` (full-mesh
                     rps / 1-device rps) is a don't-collapse bound, not a
                     speedup claim — forced host devices share the same
                     cores, so the floor only trips if SPMD overhead
                     (collectives, resharding) eats the throughput.  On an
                     unforced single-device run the ratio degenerates to
                     ~1.0 and still clears the floor.

    PYTHONPATH=src python -m benchmarks.serving                  # all scenarios
    PYTHONPATH=src python -m benchmarks.serving --scenario mixed-priority
    BENCH_TINY=1 PYTHONPATH=src python -m benchmarks.serving

Single-scenario runs merge their section into an existing
``BENCH_serving.json`` so the gate's other bounds keep their figures.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from collections import deque

import numpy as np
import jax

from benchmarks.common import emit, json_path, write_json
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import secstr_like
from repro.serving import (DeadlineExceeded, EngineFleet, PropagateEngine,
                           PropagateRequest, ShardedPropagateEngine)

TINY = bool(os.environ.get("BENCH_TINY"))
N = 256 if TINY else 4096
LP_ITERS = 10 if TINY else 50
N_REQUESTS = 32 if TINY else 96       # population served per uniform run
CONCURRENCY = (1, 4, 8) if TINY else (1, 4, 16)
MAX_BATCH = 32
MAX_WAIT_MS = 25.0   # linger cap; the rate-adaptive window stays below it
WIDTHS = (1, 2, 3, 4, 6, 8)           # mixed: exercises width buckets + padding
ALPHAS = (0.01, 0.05, 0.2)

# mixed-priority / deadline-heavy load shape: a deep low-priority backlog
# (LOW_CLIENTS x PIPELINE outstanding) against a small dispatch quantum, so
# queueing — the thing the disciplines differ on — dominates latency
QOS_WIDTH = 4
QOS_MAX_BATCH = 4
LOW_CLIENTS = 6
PIPELINE = 6
HI_COUNT = 30 if TINY else 24
TIGHT_DEADLINE_MS = 100.0 if TINY else 5000.0

# preempt scenario: bulk scans long enough that an urgent deadline cannot
# survive waiting one out (tiny N=256 runs ~0.2ms/iter, so 2000 iterations
# keeps a scan several deadline-lengths long), segments short enough that
# the urgent request easily survives one segment boundary
BULK_ITERS = 2000 if TINY else 500
SEGMENT_ITERS = 25
URGENT_DEADLINE_MS = 100.0 if TINY else 5000.0
URGENT_COUNT = 12 if TINY else 24
BULK_CLIENTS = 2

# multi-tenant scenario: weights must sum small and integer-ratio so the
# expected shares are exact; clients per tenant x pipeline keeps every
# tenant's queue several dispatch quanta deep, the regime where DRR's
# share guarantee applies
TENANT_WEIGHTS = (("gold", 3.0), ("silver", 1.0), ("bronze", 1.0))
TENANT_CLIENTS = 2
FLEET_PIPELINE = 8
FLEET_MEASURE_S = 2.0 if TINY else 4.0

# streaming scenario: each mutation cycle deletes STREAM_K rows then inserts
# STREAM_K fresh points (delete-first, so the freed leaf slots are the
# insertion headroom and N is constant at every publish — the serving
# executables never see a new shape), publishes the new epoch, and the cycle
# wall time is the A/B figure: incremental patch vs full refit of the same
# final point set.
STREAM_K = 8
STREAM_CYCLES = 4 if TINY else 6
STREAM_CLIENTS = 2
STREAM_PIPELINE = 4

# sharded scenario: uniform-width closed-loop load (one width bucket keeps
# the per-mesh warmup to a handful of SPMD compiles) served at two mesh
# sizes; the A/B figure is the full-mesh / 1-device throughput ratio
SHARD_REQUESTS = 24 if TINY else 48
SHARD_CLIENTS = 4
SHARD_MAX_BATCH = 8

SCENARIOS = ("uniform", "bursty", "mixed-priority", "deadline-heavy",
             "multi-tenant", "preempt", "streaming", "sharded")


def make_requests(rng, count):
    reqs = []
    for _ in range(count):
        c = int(rng.choice(WIDTHS))
        y0 = (rng.rand(N, c) > 0.9).astype(np.float32)
        reqs.append(PropagateRequest(y0, alpha=float(rng.choice(ALPHAS)),
                                     n_iters=LP_ITERS))
    return reqs


def _qos_seed(rng):
    return (rng.rand(N, QOS_WIDTH) > 0.9).astype(np.float32)


# ------------------------------------------------------------------ uniform
def bench_serial(vdt, requests) -> float:
    """Naive per-request loop; returns wall seconds for the whole set."""
    for c in sorted(set(r.y0.shape[1] for r in requests)):  # warm each shape
        jax.block_until_ready(vdt.label_propagate(
            np.zeros((N, c), np.float32), alpha=0.01, n_iters=LP_ITERS))
    t0 = time.perf_counter()
    for req in requests:
        jax.block_until_ready(vdt.label_propagate(
            req.y0, alpha=req.alpha, n_iters=req.n_iters))
    return time.perf_counter() - t0


def bench_engine(vdt, requests, concurrency: int) -> dict:
    """K closed-loop clients against a fresh engine; returns stats."""
    with PropagateEngine(vdt, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=4 * MAX_BATCH) as eng:
        # compile every (batch bucket, width bucket) executable up front so
        # the measured window contains zero compiles (serial gets the same
        # courtesy in bench_serial)
        eng.warmup(widths=WIDTHS, n_iters=(LP_ITERS,))

        def client(cid):
            for req in requests[cid::concurrency]:
                eng.submit(req).result(timeout=600)

        before = eng.metrics()
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(concurrency)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        m = eng.metrics()

    return {
        "concurrency": concurrency,
        "wall_s": wall,
        "throughput_rps": len(requests) / wall,
        "latency_p50_ms": m.latency_p50_ms,
        "latency_p95_ms": m.latency_p95_ms,
        "dispatches": m.dispatches - before.dispatches,
        "batch_occupancy": (m.batched_requests - before.batched_requests)
                           / max(1, m.dispatches - before.dispatches),
    }


def scenario_uniform(vdt, rng) -> dict:
    """The PR-2 parity measurement: serial loop vs engine (fifo policy)."""
    requests = make_requests(rng, N_REQUESTS)
    serial_s = bench_serial(vdt, requests)
    serial_rps = N_REQUESTS / serial_s
    emit(f"serving/serial/n={N}/r={N_REQUESTS}", serial_s * 1e6,
         f"rps={serial_rps:.1f}")

    levels = []
    for k in CONCURRENCY:
        stats = bench_engine(vdt, requests, k)
        stats["speedup_vs_serial"] = stats["throughput_rps"] / serial_rps
        levels.append(stats)
        emit(f"serving/engine/n={N}/r={N_REQUESTS}/clients={k}",
             stats["wall_s"] * 1e6,
             f"rps={stats['throughput_rps']:.1f} "
             f"speedup={stats['speedup_vs_serial']:.2f}x "
             f"occupancy={stats['batch_occupancy']:.1f} "
             f"p95={stats['latency_p95_ms']:.0f}ms")
    return {
        "serial_s": serial_s, "serial_rps": serial_rps, "levels": levels,
        # gate figures: engine throughput + batching at the highest load
        "speedup": levels[-1]["speedup_vs_serial"],
        "occupancy": levels[-1]["batch_occupancy"],
    }


# ------------------------------------------------------------------- bursty
def scenario_bursty(vdt, rng) -> dict:
    """Burst arrivals with idle gaps: the adaptive linger must coalesce
    each burst instead of dispatching its head solo."""
    clients, bursts, burst_size = 4, 5, 8
    seeds = [_qos_seed(rng) for _ in range(clients)]
    with PropagateEngine(vdt, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=4 * MAX_BATCH) as eng:
        eng.warmup(widths=(QOS_WIDTH,), n_iters=(LP_ITERS,))
        before = eng.metrics()

        def client(cid):
            for _ in range(bursts):
                futs = [eng.submit(PropagateRequest(
                    seeds[cid], alpha=0.05, n_iters=LP_ITERS))
                    for _ in range(burst_size)]
                for f in futs:
                    f.result(timeout=600)
                time.sleep(0.03)  # inter-burst quiet period

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        m = eng.metrics()
    total = clients * bursts * burst_size
    dispatches = m.dispatches - before.dispatches
    occupancy = (m.batched_requests - before.batched_requests) / max(1, dispatches)
    emit(f"serving/bursty/n={N}/bursts={clients}x{bursts}x{burst_size}",
         wall * 1e6,
         f"occupancy={occupancy:.1f} p95={m.latency_p95_ms:.0f}ms")
    return {
        "requests": total, "wall_s": wall, "dispatches": dispatches,
        "occupancy": occupancy, "latency_p95_ms": m.latency_p95_ms,
    }


# ----------------------------------------------------- qos load harness
def _qos_run(vdt, policy, rng, *, fg_request, fg_count, fg_timeout=600.0):
    """Shared mixed-priority / deadline-heavy harness.

    LOW_CLIENTS closed-loop background clients keep PIPELINE requests
    outstanding each (a stable backlog several dispatch quanta deep) while
    one foreground client runs ``fg_count`` closed-loop requests built by
    ``fg_request()``.  Returns per-foreground-request latencies (seconds)
    and the count of expired (DeadlineExceeded) requests.  The load shape
    is IDENTICAL whatever the policy — only the engine's discipline
    changes, so cross-policy comparisons are at equal offered load.
    """
    seeds = [_qos_seed(rng) for _ in range(LOW_CLIENTS)]
    latencies, expired = [], 0
    with PropagateEngine(vdt, max_batch=QOS_MAX_BATCH, max_wait_ms=5.0,
                         max_queue=512, policy=policy) as eng:
        eng.warmup(widths=(QOS_WIDTH,), n_iters=(LP_ITERS,))
        stop = threading.Event()

        def background(cid):
            futs = deque()
            while not stop.is_set():
                while len(futs) < PIPELINE:
                    futs.append(eng.submit(PropagateRequest(
                        seeds[cid], alpha=0.05, n_iters=LP_ITERS,
                        priority=0)))
                futs.popleft().result(timeout=600)
            while futs:
                futs.popleft().result(timeout=600)

        threads = [threading.Thread(target=background, args=(i,))
                   for i in range(LOW_CLIENTS)]
        for t in threads:
            t.start()
        time.sleep(0.3)  # let the backlog build before measuring
        for _ in range(fg_count):
            req = fg_request()
            t0 = time.perf_counter()
            try:
                eng.submit(req).result(timeout=fg_timeout)
                latencies.append(time.perf_counter() - t0)
            except DeadlineExceeded:
                expired += 1
        stop.set()
        for t in threads:
            t.join()
    return latencies, expired


def scenario_mixed_priority(vdt, rng) -> dict:
    """High-priority p95 under fifo vs priority at equal offered load."""
    fg_seed = _qos_seed(rng)
    out = {}
    for policy in ("fifo", "priority"):
        lat, _ = _qos_run(
            vdt, policy, rng,
            fg_request=lambda: PropagateRequest(
                fg_seed, alpha=0.05, n_iters=LP_ITERS, priority=5),
            fg_count=HI_COUNT)
        p50 = float(np.percentile(lat, 50) * 1e3)
        p95 = float(np.percentile(lat, 95) * 1e3)
        out[f"{policy}_hi_p50_ms"] = p50
        out[f"{policy}_hi_p95_ms"] = p95
        emit(f"serving/mixed-priority/{policy}/n={N}", p95 * 1e3,
             f"hi_p50={p50:.0f}ms hi_p95={p95:.0f}ms")
    # the acceptance figure: priority must at least halve FIFO's hi-pri p95
    out["hi_p95_improvement"] = out["fifo_hi_p95_ms"] / out["priority_hi_p95_ms"]
    emit(f"serving/mixed-priority/improvement/n={N}",
         out["priority_hi_p95_ms"] * 1e3,
         f"fifo_p95/priority_p95={out['hi_p95_improvement']:.2f}x")
    return out


def scenario_deadline_heavy(vdt, rng) -> dict:
    """Deadline miss rate of tight-deadline traffic under fifo vs edf.

    A miss is an expired fast-fail (edf) or a completion later than the
    request's deadline (any policy) — both measured at the client.
    """
    fg_seed = _qos_seed(rng)
    out = {}
    for policy in ("fifo", "edf"):
        lat, expired = _qos_run(
            vdt, policy, rng,
            fg_request=lambda: PropagateRequest(
                fg_seed, alpha=0.05, n_iters=LP_ITERS,
                deadline_ms=TIGHT_DEADLINE_MS),
            fg_count=HI_COUNT)
        late = sum(1 for s in lat if s * 1e3 > TIGHT_DEADLINE_MS)
        miss_rate = (expired + late) / HI_COUNT
        key = "deadline_miss_rate" if policy == "edf" \
            else "fifo_deadline_miss_rate"
        out[key] = miss_rate
        out[f"{policy}_expired"] = expired
        out[f"{policy}_late"] = late
        emit(f"serving/deadline-heavy/{policy}/n={N}",
             float(np.mean(lat) * 1e6) if lat else float("nan"),
             f"miss_rate={miss_rate:.2f} expired={expired} late={late} "
             f"deadline={TIGHT_DEADLINE_MS:.0f}ms")
    out["tight_deadline_ms"] = TIGHT_DEADLINE_MS
    return out


# ------------------------------------------------------------- multi-tenant
def scenario_multi_tenant(vdt, rng) -> dict:
    """Weighted fair sharing across tenants of one fleet, one fitted tree.

    Every tenant runs the same closed-loop load shape
    (``TENANT_CLIENTS`` clients x ``FLEET_PIPELINE`` outstanding), so
    demand exceeds fleet capacity for each tenant individually and the
    measured throughput split is purely the DRR scheduler's doing.  The
    window figures come from differencing two fleet metrics snapshots
    (lifetime counters include warmup traffic; the window does not).
    """
    weights = dict(TENANT_WEIGHTS)
    wsum = sum(weights.values())
    seeds = {name: [_qos_seed(rng) for _ in range(TENANT_CLIENTS)]
             for name in weights}
    fleet = EngineFleet(quantum=float(QOS_MAX_BATCH))
    engines = {}
    for name, w in TENANT_WEIGHTS:
        engines[name] = fleet.register(
            name, vdt, weight=w, max_batch=QOS_MAX_BATCH, max_wait_ms=5.0,
            max_queue=512)
        engines[name].warmup(widths=(QOS_WIDTH,), n_iters=(LP_ITERS,))
    stop = threading.Event()

    def client(tenant, cid):
        futs = deque()
        while not stop.is_set():
            while len(futs) < FLEET_PIPELINE:
                futs.append(fleet.submit(PropagateRequest(
                    seeds[tenant][cid], alpha=0.05, n_iters=LP_ITERS,
                    tenant=tenant)))
            futs.popleft().result(timeout=600)
        while futs:
            futs.popleft().result(timeout=600)

    threads = [threading.Thread(target=client, args=(name, cid))
               for name in weights for cid in range(TENANT_CLIENTS)]
    for t in threads:
        t.start()
    time.sleep(0.5)  # let every tenant's backlog build before measuring
    before = fleet.metrics()
    time.sleep(FLEET_MEASURE_S)
    after = fleet.metrics()
    stop.set()
    for t in threads:
        t.join()
    fleet.shutdown()

    tenants, total = {}, 0
    for name in weights:
        done = after.tenants[name].completed - before.tenants[name].completed
        total += done
        tenants[name] = {"completed": done}
    err = 0.0
    for name, w in weights.items():
        expected = w / wsum
        share = tenants[name]["completed"] / max(1, total)
        err = max(err, abs(share - expected) / expected)
        disp = (after.tenants[name].dispatches
                - before.tenants[name].dispatches)
        batched = (after.tenants[name].batched_requests
                   - before.tenants[name].batched_requests)
        tenants[name].update({
            "share": share,
            "expected_share": expected,
            "latency_p50_ms": after.tenants[name].latency_p50_ms,
            "latency_p95_ms": after.tenants[name].latency_p95_ms,
            "occupancy": batched / max(1, disp),
        })
        emit(f"serving/multi-tenant/{name}/n={N}/w={w:g}",
             after.tenants[name].latency_p95_ms * 1e3,
             f"share={share:.3f} (expected {expected:.3f}) "
             f"completed={tenants[name]['completed']} "
             f"p95={after.tenants[name].latency_p95_ms:.0f}ms "
             f"occupancy={tenants[name]['occupancy']:.1f}")
    emit(f"serving/multi-tenant/fair_share_err/n={N}", err * 1e6,
         f"err={err:.3f} window={FLEET_MEASURE_S:.1f}s "
         f"total={total} rounds={after.rounds - before.rounds}")
    return {
        "weights": {name: w for name, w in TENANT_WEIGHTS},
        "window_s": FLEET_MEASURE_S,
        "completed_in_window": total,
        "rounds_in_window": after.rounds - before.rounds,
        "fair_share_err": err,
        "lifetime_fair_share_err": after.fair_share_err,
        "tenants": tenants,
    }


# ----------------------------------------------------------------- preempt
def scenario_preempt(vdt, rng) -> dict:
    """Urgent-arrival latency against in-flight long scans, mono vs segmented.

    ``BULK_CLIENTS`` closed-loop clients keep ``BULK_ITERS``-iteration
    scans on the device back to back, so a tight-deadline foreground
    request almost always lands MID-scan.  Under monolithic EDF dispatch
    the arrival can only reorder the *queue* — it still waits out (and,
    with a deadline shorter than a bulk scan, typically expires behind)
    the in-flight work.  With ``segment_iters`` the engine re-checks the
    queue every segment and yields, so the urgent request completes within
    roughly one segment plus its own dispatch.  The gated figure is the
    p95 of completed urgent-request latencies in the segmented run
    (``p95_preempt_ms``); the monolithic run's completion/expiry split is
    recorded alongside as the head-of-line-blocking baseline.
    """
    fg_seed = _qos_seed(rng)
    bulk_seeds = [_qos_seed(rng) for _ in range(BULK_CLIENTS)]
    out = {"bulk_iters": BULK_ITERS, "segment_iters": SEGMENT_ITERS,
           "urgent_deadline_ms": URGENT_DEADLINE_MS}
    for mode, seg in (("monolithic", None), ("preempt", SEGMENT_ITERS)):
        latencies, expired = [], 0
        with PropagateEngine(vdt, max_batch=QOS_MAX_BATCH, max_wait_ms=5.0,
                             max_queue=64, policy="edf",
                             segment_iters=seg) as eng:
            eng.warmup(widths=(QOS_WIDTH,), n_iters=(LP_ITERS, BULK_ITERS))
            stop = threading.Event()

            def background(cid):
                futs = deque()
                while not stop.is_set():
                    while len(futs) < 2:  # always one scan queued behind
                        futs.append(eng.submit(PropagateRequest(
                            bulk_seeds[cid], alpha=0.05,
                            n_iters=BULK_ITERS)))
                    futs.popleft().result(timeout=600)
                while futs:
                    futs.popleft().result(timeout=600)

            threads = [threading.Thread(target=background, args=(i,))
                       for i in range(BULK_CLIENTS)]
            for t in threads:
                t.start()
            time.sleep(0.2)  # let a bulk scan get in flight first
            for _ in range(URGENT_COUNT):
                t0 = time.perf_counter()
                try:
                    eng.submit(PropagateRequest(
                        fg_seed, alpha=0.05, n_iters=LP_ITERS,
                        deadline_ms=URGENT_DEADLINE_MS)).result(timeout=600)
                    latencies.append(time.perf_counter() - t0)
                except DeadlineExceeded:
                    expired += 1
                time.sleep(0.02)  # spread arrivals across scan interiors
            stop.set()
            for t in threads:
                t.join()
            m = eng.metrics()
        p95 = float(np.percentile(latencies, 95) * 1e3) \
            if latencies else float("nan")
        p50 = float(np.percentile(latencies, 50) * 1e3) \
            if latencies else float("nan")
        out[f"{mode}_p50_ms"] = p50
        out[f"{mode}_p95_ms"] = p95
        out[f"{mode}_completed"] = len(latencies)
        out[f"{mode}_expired"] = expired
        if mode == "preempt":
            out["p95_preempt_ms"] = p95  # the gated figure
            out["preemptions"] = m.preemptions
            out["preempt_iters"] = m.preempt_iters
        emit(f"serving/preempt/{mode}/n={N}/bulk={BULK_ITERS}",
             p95 * 1e3 if latencies else float("nan"),
             f"p50={p50:.0f}ms p95={p95:.0f}ms completed={len(latencies)} "
             f"expired={expired}"
             + (f" preemptions={m.preemptions}" if mode == "preempt" else ""))
    return out


# --------------------------------------------------------------- streaming
def scenario_streaming(vdt, rng) -> dict:
    """Online model updates under closed-loop serving load: patch vs refit.

    Both arms run the IDENTICAL load shape — ``STREAM_CLIENTS`` closed-loop
    clients keep ``STREAM_PIPELINE`` requests outstanding each while
    ``STREAM_CYCLES`` mutation cycles (delete ``STREAM_K`` rows, insert
    ``STREAM_K`` new points, publish the result as a new epoch) run on the
    benchmark thread — and differ only in how the published model is
    produced:

    ``patch``  the streaming layer's O(k d log N) incremental insert/delete
               (``core/streaming.py``), re-optimizing q from patched stats;
    ``refit``  a from-scratch ``VariationalDualTree.fit`` of the same final
               point set at the same block budget and bandwidth — what a
               deployment without incremental updates would have to do.

    The gated figure is ``patch_speedup`` = refit cycle mean / patch cycle
    mean: the factor by which incremental maintenance beats refitting while
    traffic keeps flowing.  Epoch correctness rides along: every client
    request completes (in-flight entries finish on their pinned epoch), and
    the epoch metrics recorded per arm let the gate's consumers confirm all
    publishes landed and all old epochs retired.
    """
    sigma = float(vdt.sigma)
    max_blocks = 4 * N
    width = QOS_WIDTH
    out = {"cycles": STREAM_CYCLES, "points_per_cycle": 2 * STREAM_K}
    for mode in ("patch", "refit"):
        x_cur = np.asarray(vdt.x_rows, np.float32).copy()
        model = vdt
        mut_s = []
        with PropagateEngine(vdt, max_batch=QOS_MAX_BATCH, max_wait_ms=5.0,
                             max_queue=512) as eng:
            eng.warmup(widths=(width,), n_iters=(LP_ITERS,))
            stop = threading.Event()
            seed = _qos_seed(rng)

            def client(cid):
                futs = deque()
                while not stop.is_set():
                    while len(futs) < STREAM_PIPELINE:
                        futs.append(eng.submit(PropagateRequest(
                            seed, alpha=0.05, n_iters=LP_ITERS)))
                    futs.popleft().result(timeout=600)
                while futs:
                    futs.popleft().result(timeout=600)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(STREAM_CLIENTS)]
            for t in threads:
                t.start()
            time.sleep(0.2)  # let serving traffic get in flight first
            # one untimed warmup cycle absorbs the arm's one-off compiles
            # (the streaming q re-optimization / the refit pipeline)
            for cycle in range(STREAM_CYCLES + 1):
                rows = np.sort(rng.choice(N, STREAM_K, replace=False))
                x_new = x_cur[rows] + rng.randn(STREAM_K, x_cur.shape[1]) \
                    .astype(np.float32) * 0.05
                t0 = time.perf_counter()
                if mode == "patch":
                    upd = model.delete_points(rows)
                    upd = upd.vdt.insert_points(x_new)
                    model = upd.vdt
                    eng.publish(model, patched_points=2 * STREAM_K,
                                stale_blocks=upd.stale_blocks)
                else:
                    x_cur = np.vstack([np.delete(x_cur, rows, axis=0), x_new])
                    model = VariationalDualTree.fit(
                        x_cur, max_blocks=max_blocks, sigma=sigma,
                        learn_sigma=False,
                        refine_batch=64 if TINY else 256)
                    eng.publish(model, patched_points=2 * STREAM_K)
                dt = time.perf_counter() - t0
                if cycle > 0:
                    mut_s.append(dt)
                if mode == "patch":
                    # keep the host mirror in step for the delete sampling
                    keep = np.ones(len(x_cur), bool)
                    keep[rows] = False
                    x_cur = np.vstack([x_cur[keep], x_new])
            stop.set()
            for t in threads:
                t.join()
            m = eng.metrics()
        mean_ms = float(np.mean(mut_s) * 1e3)
        p95_ms = float(np.percentile(mut_s, 95) * 1e3)
        out[f"{mode}_mut_mean_ms"] = mean_ms
        out[f"{mode}_mut_p95_ms"] = p95_ms
        out[f"{mode}_completed"] = m.completed
        out[f"{mode}_failed"] = m.failed
        out[f"{mode}_epochs_published"] = m.epochs_published
        out[f"{mode}_epochs_retired"] = m.epochs_retired
        out[f"{mode}_final_live_epochs"] = m.live_epochs
        emit(f"serving/streaming/{mode}/n={N}/k={STREAM_K}", mean_ms * 1e3,
             f"mut_mean={mean_ms:.1f}ms mut_p95={p95_ms:.1f}ms "
             f"completed={m.completed} failed={m.failed} "
             f"epochs={m.epochs_published}")
    out["patch_speedup"] = out["refit_mut_mean_ms"] / out["patch_mut_mean_ms"]
    emit(f"serving/streaming/speedup/n={N}", out["patch_mut_mean_ms"] * 1e3,
         f"patch_speedup={out['patch_speedup']:.2f}x")
    return out


# ------------------------------------------------------------------ sharded
def scenario_sharded(vdt, rng) -> dict:
    """Full-mesh vs 1-device-mesh ShardedPropagateEngine at equal load.

    Both arms run the SAME engine class (so the A/B isolates the mesh size,
    not single-device-engine vs sharded-engine code-path differences) and
    the SAME closed-loop request population.  ``scaling_floor`` — full-mesh
    throughput over 1-device throughput — is the gated figure; see the
    module docstring for why its committed bound is a collapse detector
    rather than a speedup target on forced host devices.
    """
    seed = _qos_seed(rng)
    requests = [PropagateRequest(seed, alpha=float(rng.choice(ALPHAS)),
                                 n_iters=LP_ITERS)
                for _ in range(SHARD_REQUESTS)]

    def measure(devices, label):
        with ShardedPropagateEngine(
                vdt, devices=devices, max_batch=SHARD_MAX_BATCH,
                max_wait_ms=MAX_WAIT_MS, max_queue=64) as eng:
            n_dev = eng.n_devices
            eng.warmup(widths=(QOS_WIDTH,), n_iters=(LP_ITERS,))

            def client(cid):
                for req in requests[cid::SHARD_CLIENTS]:
                    eng.submit(req).result(timeout=600)

            t0 = time.perf_counter()
            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(SHARD_CLIENTS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            m = eng.metrics()
        rps = len(requests) / wall
        emit(f"serving/sharded/{label}/n={N}/d={n_dev}", wall * 1e6,
             f"rps={rps:.1f} p95={m.latency_p95_ms:.0f}ms")
        return {"devices": n_dev, "wall_s": wall, "throughput_rps": rps,
                "latency_p95_ms": m.latency_p95_ms}

    single = measure(jax.devices()[:1], "single")
    full = measure(None, "full-mesh")
    scaling = full["throughput_rps"] / single["throughput_rps"]
    emit(f"serving/sharded/scaling/n={N}/d={full['devices']}",
         full["wall_s"] * 1e6, f"scaling={scaling:.2f}x")
    return {"single": single, "full": full, "scaling_floor": scaling}


# ---------------------------------------------------------------- top level
def run(scenarios=SCENARIOS) -> dict:
    rng = np.random.RandomState(0)
    data = secstr_like(n=N, d=64 if TINY else 315)
    x = np.asarray(data.x[:N])

    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(x, max_blocks=4 * N,
                                  refine_batch=64 if TINY else 256)
    emit("serving/fit", (time.perf_counter() - t0) * 1e6,
         f"blocks={vdt.n_blocks}")

    sections = {}
    if "uniform" in scenarios:
        sections["fifo"] = scenario_uniform(vdt, rng)
    if "bursty" in scenarios:
        sections["bursty"] = scenario_bursty(vdt, rng)
    if "mixed-priority" in scenarios:
        sections["mixed_priority"] = scenario_mixed_priority(vdt, rng)
    if "deadline-heavy" in scenarios:
        sections["edf"] = scenario_deadline_heavy(vdt, rng)
    if "multi-tenant" in scenarios:
        sections["fleet"] = scenario_multi_tenant(vdt, rng)
    if "preempt" in scenarios:
        sections["preempt"] = scenario_preempt(vdt, rng)
    if "streaming" in scenarios:
        sections["streaming"] = scenario_streaming(vdt, rng)
    if "sharded" in scenarios:
        sections["sharded"] = scenario_sharded(vdt, rng)

    # single-scenario runs keep the other sections of an existing artifact
    # so a targeted re-measure never knocks out the gate's other bounds —
    # but only if the prior artifact was measured at THIS shape/mode, so a
    # tiny re-run can never smuggle full-size figures (or vice versa) past
    # the gate under a fresh schema stamp
    payload = {}
    prior = json_path("serving")
    if len(scenarios) < len(SCENARIOS) and os.path.exists(prior):
        with open(prior) as fh:
            prior_payload = json.load(fh)
        if prior_payload.get("n") == N and prior_payload.get("tiny") == TINY:
            payload = prior_payload
            payload.pop("schema_version", None)  # restamped by write_json
            payload.pop("tiny", None)
        else:
            print(f"not merging {prior}: measured at "
                  f"n={prior_payload.get('n')} tiny={prior_payload.get('tiny')}, "
                  f"this run is n={N} tiny={TINY}", flush=True)
    payload.update({
        "n": N, "lp_iters": LP_ITERS, "max_batch": MAX_BATCH,
        "max_wait_ms": MAX_WAIT_MS, "qos_max_batch": QOS_MAX_BATCH,
        "low_clients": LOW_CLIENTS, "pipeline": PIPELINE,
    })
    payload.update(sections)
    write_json("serving", payload)
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=SCENARIOS + ("all",), default="all",
                    help="which closed-loop scenario to run (default: all)")
    args = ap.parse_args()
    scenarios = SCENARIOS if args.scenario == "all" else (args.scenario,)
    run(scenarios)


if __name__ == "__main__":
    main()
