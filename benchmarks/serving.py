"""Continuous-batching engine under closed-loop load vs a per-request loop.

The headline PR-2 number: one fitted VDT (N=4096 full / N=256 tiny) serves a
population of mixed-width, mixed-alpha LP requests two ways —

  serial:  a naive per-request loop, ``vdt.label_propagate`` one request at
           a time (what a user without the engine would write);
  engine:  ``PropagateEngine`` fed by K closed-loop client threads (each
           submits, blocks on its future, submits the next), for K in
           ``CONCURRENCY`` — offered load scales with K.

Both sides are warmed first so compile time is excluded; the engine's jit
executables are bounded by the width/batch buckets either way.  Emits CSV
lines like the other benchmarks and writes ``BENCH_serving.json`` with
throughput, latency quantiles, batch occupancy, and the speedup-vs-serial
per concurrency level — the CI bench-gate artifact.

    PYTHONPATH=src python -m benchmarks.serving          # full (N=4096)
    BENCH_TINY=1 PYTHONPATH=src python -m benchmarks.serving
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np
import jax

from benchmarks.common import emit, write_json
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import secstr_like
from repro.serving.engine import PropagateEngine
from repro.serving.propagate import PropagateRequest

TINY = bool(os.environ.get("BENCH_TINY"))
N = 256 if TINY else 4096
LP_ITERS = 10 if TINY else 50
N_REQUESTS = 32 if TINY else 96       # population served per measurement
CONCURRENCY = (1, 4, 8) if TINY else (1, 4, 16)
MAX_BATCH = 32
MAX_WAIT_MS = 25.0   # linger cap; the adaptive quiesce window ends it early
WIDTHS = (1, 2, 3, 4, 6, 8)           # mixed: exercises width buckets + padding
ALPHAS = (0.01, 0.05, 0.2)


def make_requests(rng, count):
    reqs = []
    for _ in range(count):
        c = int(rng.choice(WIDTHS))
        y0 = (rng.rand(N, c) > 0.9).astype(np.float32)
        reqs.append(PropagateRequest(y0, alpha=float(rng.choice(ALPHAS)),
                                     n_iters=LP_ITERS))
    return reqs


def bench_serial(vdt, requests) -> float:
    """Naive per-request loop; returns wall seconds for the whole set."""
    for c in sorted(set(r.y0.shape[1] for r in requests)):  # warm each shape
        jax.block_until_ready(vdt.label_propagate(
            np.zeros((N, c), np.float32), alpha=0.01, n_iters=LP_ITERS))
    t0 = time.perf_counter()
    for req in requests:
        jax.block_until_ready(vdt.label_propagate(
            req.y0, alpha=req.alpha, n_iters=req.n_iters))
    return time.perf_counter() - t0


def bench_engine(vdt, requests, concurrency: int) -> dict:
    """K closed-loop clients against a fresh engine; returns stats."""
    with PropagateEngine(vdt, max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=4 * MAX_BATCH) as eng:
        # compile every (batch bucket, width bucket) executable up front so
        # the measured window contains zero compiles (serial gets the same
        # courtesy in bench_serial)
        eng.warmup(widths=WIDTHS, n_iters=(LP_ITERS,))

        def client(cid):
            for req in requests[cid::concurrency]:
                eng.submit(req).result(timeout=600)

        before = eng.metrics()
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(concurrency)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        m = eng.metrics()

    return {
        "concurrency": concurrency,
        "wall_s": wall,
        "throughput_rps": len(requests) / wall,
        "latency_p50_ms": m.latency_p50_ms,
        "latency_p95_ms": m.latency_p95_ms,
        "dispatches": m.dispatches - before.dispatches,
        "batch_occupancy": (m.batched_requests - before.batched_requests)
                           / max(1, m.dispatches - before.dispatches),
    }


def run():
    rng = np.random.RandomState(0)
    data = secstr_like(n=N, d=64 if TINY else 315)
    x = np.asarray(data.x[:N])

    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(x, max_blocks=4 * N,
                                  refine_batch=64 if TINY else 256)
    emit("serving/fit", (time.perf_counter() - t0) * 1e6,
         f"blocks={vdt.n_blocks}")

    requests = make_requests(rng, N_REQUESTS)

    serial_s = bench_serial(vdt, requests)
    serial_rps = N_REQUESTS / serial_s
    emit(f"serving/serial/n={N}/r={N_REQUESTS}", serial_s * 1e6,
         f"rps={serial_rps:.1f}")

    levels = []
    for k in CONCURRENCY:
        stats = bench_engine(vdt, requests, k)
        stats["speedup_vs_serial"] = stats["throughput_rps"] / serial_rps
        levels.append(stats)
        emit(f"serving/engine/n={N}/r={N_REQUESTS}/clients={k}",
             stats["wall_s"] * 1e6,
             f"rps={stats['throughput_rps']:.1f} "
             f"speedup={stats['speedup_vs_serial']:.2f}x "
             f"occupancy={stats['batch_occupancy']:.1f} "
             f"p95={stats['latency_p95_ms']:.0f}ms")

    write_json("serving", {
        "n": N, "requests": N_REQUESTS, "lp_iters": LP_ITERS,
        "max_batch": MAX_BATCH, "max_wait_ms": MAX_WAIT_MS,
        "serial_s": serial_s, "serial_rps": serial_rps,
        "levels": levels,
        # gate figures: engine throughput + batching at the highest load
        "speedup": levels[-1]["speedup_vs_serial"],
        "occupancy": levels[-1]["batch_occupancy"],
    })


if __name__ == "__main__":
    run()
