"""Paper Fig. 2D-K: refinement cost and CCR vs refinement level, on the
Digit1-like and USPS-like surrogates (1500 x 241, 2 classes), for
VariationalDT vs kNN, at 10 and 100 labels."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.baselines import build_knn_graph, knn_matvec
from repro.core.label_prop import ccr, label_propagate, one_hot_labels
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import digit1_like, usps_like

import os
FAST = os.environ.get("BENCH_FAST", "0") == "1"
N = 1500
ALPHA, ITERS = 0.01, 200 if FAST else 500
LEVELS = (2, 6) if FAST else (2, 4, 6, 8)   # |B| = k*N <-> kNN k


def run():
    rng = np.random.RandomState(1)
    for ds_name, ds in (("digit1", digit1_like(n=N)),
                        ("usps", usps_like(n=N))):
        x = jnp.asarray(ds.x)
        labels = ds.labels
        vdt = VariationalDualTree.fit(x)  # coarsest; sigma learned
        sig = jnp.asarray(vdt.sigma)

        for n_lab in (10, 100):
            labeled = np.zeros(N, bool)
            labeled[rng.choice(N, n_lab, replace=False)] = True
            y0 = one_hot_labels(labels, labeled, ds.n_classes)

            v = VariationalDualTree.fit(x, sigma=float(sig),
                                        learn_sigma=False)
            for k in LEVELS:
                t0 = time.perf_counter()
                v.refine(max_blocks=k * N)
                us_ref = (time.perf_counter() - t0) * 1e6
                yf = label_propagate(v.matvec, y0, ALPHA, ITERS)
                acc = ccr(yf, labels, ~labeled)
                emit(f"fig2d-k/{ds_name}/vdt/labels={n_lab}/k={k}", us_ref,
                     f"ccr={acc:.4f},blocks={v.n_blocks}")

            for k in LEVELS:
                t0 = time.perf_counter()
                g = build_knn_graph(x, k, sig)
                g.weights.block_until_ready()
                us_ref = (time.perf_counter() - t0) * 1e6
                yf = label_propagate(lambda y: knn_matvec(g, y), y0,
                                     ALPHA, ITERS)
                acc = ccr(yf, labels, ~labeled)
                emit(f"fig2d-k/{ds_name}/knn/labels={n_lab}/k={k}", us_ref,
                     f"ccr={acc:.4f}")


if __name__ == "__main__":
    run()
