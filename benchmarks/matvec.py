"""Paper Fig. 2B: transition-matrix matvec time vs N (exact vs kNN vs VDT),
plus the fused Pallas exact-matvec kernel (beyond paper) and the batched
multi-RHS engine (one dispatch vs a loop of single-RHS calls).

Set BENCH_TINY=1 for a seconds-long CI smoke run (small N, batched section
only at the single size).  Writes ``BENCH_matvec.json`` with the
batched-vs-loop speedups per size — the figures the CI bench-gate compares
against ``benchmarks/baselines.json``."""
from __future__ import annotations

import os

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_json
from repro.core.baselines import (build_knn_graph, exact_transition_matrix,
                                  knn_matvec, streaming_exact_matvec)
from repro.core.sigma import sigma_init
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import secstr_like

TINY = bool(os.environ.get("BENCH_TINY"))
SIZES = (256,) if TINY else (1000, 4000, 16000)
C = 2
BATCH = 8       # multi-RHS stack size for the batched engine section
LP_ITERS = 5 if TINY else 50


def _bench_batched(vdt, n: int) -> dict:
    """Batched (BATCH, N, C) engine vs BATCH looped single-RHS calls."""
    r = np.random.RandomState(0)
    ys = jnp.asarray(r.randn(BATCH, n, C).astype(np.float32))

    def loop(stack):
        return [vdt.matvec(stack[i]) for i in range(BATCH)]

    us_loop = timeit(loop, ys)
    us_bat = timeit(vdt.matvec_batched, ys)
    emit(f"batched/matvec/loop/n={n}/b={BATCH}", us_loop, "")
    emit(f"batched/matvec/batched/n={n}/b={BATCH}", us_bat,
         f"speedup={us_loop / us_bat:.2f}x")

    y0 = jnp.asarray((r.rand(BATCH, n, C) > 0.9).astype(np.float32))

    def lp_loop(stack):
        return [vdt.label_propagate(stack[i], n_iters=LP_ITERS)
                for i in range(BATCH)]

    def lp_bat(stack):
        return vdt.label_propagate(stack, n_iters=LP_ITERS)

    us_l = timeit(lp_loop, y0)
    us_b = timeit(lp_bat, y0)
    emit(f"batched/lp{LP_ITERS}/loop/n={n}/b={BATCH}", us_l, "")
    emit(f"batched/lp{LP_ITERS}/batched/n={n}/b={BATCH}", us_b,
         f"speedup={us_l / us_b:.2f}x")
    return {
        "n": n, "batch": BATCH, "lp_iters": LP_ITERS,
        "matvec_loop_us": us_loop, "matvec_batched_us": us_bat,
        "matvec_speedup": us_loop / us_bat,
        "lp_loop_us": us_l, "lp_batched_us": us_b,
        "lp_speedup": us_l / us_b,
    }


def run():
    results = []
    data = secstr_like(n=max(SIZES), d=64 if TINY else 315)
    for n in SIZES:
        x = jnp.asarray(data.x[:n])
        y = jnp.asarray(data.x[:n, :C]).astype(jnp.float32)
        sig = sigma_init(x)

        vdt = VariationalDualTree.fit(x, sigma=float(sig), learn_sigma=False)
        us = timeit(vdt.matvec, y)
        emit(f"fig2b/matvec/vdt/n={n}", us, f"blocks={vdt.n_blocks}")

        results.append(_bench_batched(vdt, n))

        g = build_knn_graph(x, 2, sig)
        us = timeit(lambda yy: knn_matvec(g, yy), y)
        emit(f"fig2b/matvec/knn2/n={n}", us, "")

        if n <= 4000:
            p = exact_transition_matrix(x, sig)
            us = timeit(lambda yy: p @ yy, y)
            emit(f"fig2b/matvec/exact/n={n}", us, "")

        us = timeit(lambda yy: streaming_exact_matvec(x, yy, sig), y)
        emit(f"fig2b/matvec/exact_streaming/n={n}", us,
             "fused flash form, O(N*blk) mem")

    write_json("matvec", {
        "sizes": results,
        # gate figures: worst case over sizes, so a regression at any N trips
        "matvec_speedup": min(r["matvec_speedup"] for r in results),
        "lp_speedup": min(r["lp_speedup"] for r in results),
    })


if __name__ == "__main__":
    run()
