"""Paper Fig. 2B: transition-matrix matvec time vs N (exact vs kNN vs VDT),
plus the fused Pallas exact-matvec kernel (beyond paper)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.baselines import (build_knn_graph, exact_transition_matrix,
                                  knn_matvec, streaming_exact_matvec)
from repro.core.sigma import sigma_init
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import secstr_like

SIZES = (1000, 4000, 16000)
C = 2


def run():
    data = secstr_like(n=max(SIZES), d=315)
    for n in SIZES:
        x = jnp.asarray(data.x[:n])
        y = jnp.asarray(data.x[:n, :C]).astype(jnp.float32)
        sig = sigma_init(x)

        vdt = VariationalDualTree.fit(x, sigma=float(sig), learn_sigma=False)
        us = timeit(vdt.matvec, y)
        emit(f"fig2b/matvec/vdt/n={n}", us, f"blocks={vdt.n_blocks}")

        g = build_knn_graph(x, 2, sig)
        us = timeit(lambda yy: knn_matvec(g, yy), y)
        emit(f"fig2b/matvec/knn2/n={n}", us, "")

        if n <= 4000:
            p = exact_transition_matrix(x, sig)
            us = timeit(lambda yy: p @ yy, y)
            emit(f"fig2b/matvec/exact/n={n}", us, "")

        us = timeit(lambda yy: streaming_exact_matvec(x, yy, sig), y)
        emit(f"fig2b/matvec/exact_streaming/n={n}", us,
             "fused flash form, O(N*blk) mem")


if __name__ == "__main__":
    run()
