"""GRF walker-estimator benchmark: accuracy-vs-walkers curve + throughput.

The scenario this backend exists for: a natively sparse graph (ring +
random chords, constant out-degree) too large to materialize densely at
production scale.  Two figures feed the CI gate (``BENCH_grf.json``,
bounds under the ``grf`` section of ``benchmarks/baselines.json``):

* ``kernels.grf.rel_err_at_budget`` — relative L2 error of
  ``grf_label_propagate`` at the serving-default walker budget (m = 64)
  against the dense eq.-15 reference on the same matrix.  The CLT makes
  this budget-predictable (the MC noise only touches the series tail,
  total weight ``alpha``), so a cap well above the quiet-runner figure
  still catches a broken importance correction or coefficient schedule.
* ``kernels.grf.speedup_vs_dense`` — jitted streamed-walk LP vs the dense
  reference at the same iteration count.  Per step the walker scan does
  O(N * m) work vs O(N^2) dense, and the ratio tracks that: ~0.1x at the
  tiny N=512 shape, ~0.3x at N=2048 (per-walker threefry PRNG has a large
  constant on CPU while dense rides BLAS; the crossover sits past the
  sizes a CI runner can time).  Like ``serving.fifo.speedup``, the
  committed floor is therefore a catastrophic-degradation floor — it
  trips if the scan stops scaling linearly, not a claim that GRF beats
  dense at CI shapes.

The accuracy curve (m = 8 / 32 / 128) is recorded, not gated: it
documents the ~1/sqrt(m) decay operators size ``rtol`` budgets against.
Timings use the jnp feature oracle (``impl="ref"``) on CPU — interpret-
mode Pallas measures correctness paths, not TPU performance (see
EXPERIMENTS.md §Roofline), and the algorithmic O(N*m) vs O(N^2) contrast
is what this gate protects.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import emit, timeit, write_json
from repro.core.grf import CSRGraph, grf_label_propagate
from repro.kernels.grf.ref import dense_lp_ref

TINY = bool(os.environ.get("BENCH_TINY"))
N = 512 if TINY else 2048
DEG = 8            # constant out-degree: density DEG/N (~1.6% tiny)
C = 4
ALPHA = 0.1
N_ITERS = 10
BUDGET = 64        # the serving default the gated rel-err is measured at
CURVE = (8, 32, 128)


def sparse_ring_graph(rng, n, deg):
    """Ring + random chords: connected, sparse, non-uniform weights."""
    cols = np.empty((n, deg), np.int64)
    cols[:, 0] = (np.arange(n) + 1) % n          # ring edge: connectivity
    cols[:, 1:] = rng.randint(0, n, size=(n, deg - 1))
    indptr = np.arange(n + 1, dtype=np.int64) * deg
    weights = rng.rand(n * deg) + 0.1
    return CSRGraph.from_csr(indptr, cols.reshape(-1), weights)


def rel_err(est, want):
    est, want = np.asarray(est, np.float64), np.asarray(want, np.float64)
    return float(np.linalg.norm(est - want) / np.linalg.norm(want))


def run():
    rng = np.random.RandomState(0)
    graph = sparse_ring_graph(rng, N, DEG)
    y0 = (rng.rand(N, C) > 0.8).astype(np.float32)
    dense = graph.dense_p()
    want = np.asarray(dense_lp_ref(dense, y0, alpha=ALPHA, n_iters=N_ITERS))

    curve = {}
    for m in CURVE:
        est = grf_label_propagate(graph, y0, alpha=ALPHA, n_iters=N_ITERS,
                                  n_walkers=m, seed=1, impl="ref")
        curve[str(m)] = rel_err(est, want)
        emit(f"grf/rel_err/n={N},m={m}", 0.0, f"rel_err={curve[str(m)]:.4f}")

    est_b = grf_label_propagate(graph, y0, alpha=ALPHA, n_iters=N_ITERS,
                                n_walkers=BUDGET, seed=1, impl="ref")
    rel_err_at_budget = rel_err(est_b, want)
    emit(f"grf/rel_err_at_budget/n={N},m={BUDGET}", 0.0,
         f"rel_err={rel_err_at_budget:.4f}")

    grf_fn = jax.jit(lambda y: grf_label_propagate(
        graph, y, alpha=ALPHA, n_iters=N_ITERS, n_walkers=BUDGET, seed=1,
        impl="ref"))
    dense_fn = jax.jit(lambda y: dense_lp_ref(dense, y, alpha=ALPHA,
                                              n_iters=N_ITERS))
    y0j = np.asarray(y0)
    us_grf = timeit(grf_fn, y0j)
    us_dense = timeit(dense_fn, y0j)
    speedup = us_dense / max(us_grf, 1e-9)
    emit(f"grf/lp_streamed/n={N},m={BUDGET},iters={N_ITERS}", us_grf,
         "O(N*m) per step")
    emit(f"grf/lp_dense_ref/n={N},iters={N_ITERS}", us_dense,
         f"O(N^2) per step, speedup={speedup:.2f}x")

    write_json("grf", {
        "n": N, "deg": DEG, "c": C, "alpha": ALPHA, "n_iters": N_ITERS,
        "budget": BUDGET, "density": graph.density,
        "kernels": {
            "grf": {
                "rel_err_at_budget": rel_err_at_budget,
                "rel_err_curve": curve,
                "grf_us": us_grf,
                "dense_us": us_dense,
                "speedup_vs_dense": speedup,
            }
        },
    })


if __name__ == "__main__":
    run()
