"""Kernel microbenchmarks: Pallas (interpret on CPU) wrappers vs jnp oracles.

On this CPU container interpret-mode timings measure correctness paths, not
TPU performance — the roofline for the kernels is in EXPERIMENTS.md §Roofline.
The oracle timings still give the paper's exact-vs-streaming memory trade.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.baselines import exact_transition_matrix, streaming_exact_matvec
from repro.kernels.pairwise import pairwise_sq_dists_ref

N, D, C = 4096, 64, 4


def run():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, D), jnp.float32)
    y = jnp.asarray(rng.randn(N, C), jnp.float32)
    sig = jnp.asarray(1.5)

    us = timeit(lambda: pairwise_sq_dists_ref(x[:1024], x[:1024]))
    emit("kernels/pairwise_ref/1024x1024", us, "jnp oracle")

    p = exact_transition_matrix(x, sig)
    us_d = timeit(lambda: p @ y)
    emit(f"kernels/exact_dense_matvec/n={N}", us_d,
         f"mem={N*N*4/1e6:.0f}MB materialized")

    us_s = timeit(lambda: streaming_exact_matvec(x, y, sig, block=512))
    emit(f"kernels/exact_streaming_matvec/n={N}", us_s,
         f"mem={N*512*4/1e6:.0f}MB streaming,ratio={us_s/max(us_d,1):.2f}x")


if __name__ == "__main__":
    run()
