"""Kernel microbenchmarks: Pallas (interpret on CPU) wrappers vs jnp oracles.

On this CPU container interpret-mode timings measure correctness paths, not
TPU performance — the roofline for the kernels is in EXPERIMENTS.md §Roofline.
The oracle timings still give the paper's exact-vs-streaming memory trade.

The batched-LP section is the exception: interpret mode executes the real
kernel FLOPs, so the distance-reusing layout's ~B-fold cut in
distance/softmax work shows up even on CPU.  Its speedup over the legacy
per-batch-recompute kernel is written to ``BENCH_kernels.json`` as
``fused_lp_reuse_speedup`` and held to the committed floor in
``benchmarks/baselines.json`` by the CI bench gate.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timeit, write_json
from repro.core.baselines import exact_transition_matrix, streaming_exact_matvec
from repro.kernels.fused_lp import fused_lp_matvec_batched
from repro.kernels.pairwise import pairwise_sq_dists_ref

# the committed floor for fused_lp_reuse_speedup is DEFINED at this shape,
# so the batched section runs it even under BENCH_TINY/BENCH_FAST (a few
# kernel calls, ~1-2 min in interpret mode) — unlike matvec/serving there
# is no smaller shape that measures the same thing
N, D, C = 4096, 64, 4
BATCH = 8  # the acceptance shape: N=4096, B=8, C<=4


def run():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(N, D), jnp.float32)
    y = jnp.asarray(rng.randn(N, C), jnp.float32)
    sig = jnp.asarray(1.5)

    us = timeit(lambda: pairwise_sq_dists_ref(x[:1024], x[:1024]))
    emit("kernels/pairwise_ref/1024x1024", us, "jnp oracle")

    p = exact_transition_matrix(x, sig)
    us_d = timeit(lambda: p @ y)
    emit(f"kernels/exact_dense_matvec/n={N}", us_d,
         f"mem={N*N*4/1e6:.0f}MB materialized")

    us_s = timeit(lambda: streaming_exact_matvec(x, y, sig, block=512))
    emit(f"kernels/exact_streaming_matvec/n={N}", us_s,
         f"mem={N*512*4/1e6:.0f}MB streaming,ratio={us_s/max(us_d,1):.2f}x")

    # distance-reusing vs per-batch-recompute batched LP kernel: same math,
    # grid (M, N) with the batch folded into channels vs grid (B, M, N)
    ys = jnp.asarray(rng.randn(BATCH, N, C), jnp.float32)
    us_pb = timeit(lambda: fused_lp_matvec_batched(x, ys, 1.5, reuse=False))
    emit(f"kernels/fused_lp_batched_perbatch/n={N},b={BATCH},c={C}", us_pb,
         "grid (B,M,N): distances derived B times")
    us_re = timeit(lambda: fused_lp_matvec_batched(x, ys, 1.5, reuse=True))
    reuse_speedup = us_pb / max(us_re, 1e-9)
    emit(f"kernels/fused_lp_batched_reuse/n={N},b={BATCH},c={C}", us_re,
         f"grid (M,N) folded: speedup={reuse_speedup:.2f}x")

    # per-backend (per-divergence) reuse floors: the distance-reusing win
    # must hold for every divergence kernel the serving engine can dispatch,
    # not just the default sqeuclidean tile.  KL runs a smaller shape (the
    # tile itself is pricier in interpret mode); its floor in baselines.json
    # is proportionally softer.
    backends = {"sqeuclidean": {"n": N, "batch": BATCH, "c": C,
                                "perbatch_us": us_pb, "reuse_us": us_re,
                                "reuse_speedup": reuse_speedup}}
    kn, kb, kc = 1024, 4, 2
    x_pos = jnp.asarray(rng.rand(kn, D) + 0.1, jnp.float32)  # KL domain: > 0
    ys_kl = jnp.asarray(rng.rand(kb, kn, kc), jnp.float32)
    us_pb_kl = timeit(lambda: fused_lp_matvec_batched(
        x_pos, ys_kl, 1.5, reuse=False, divergence="kl"))
    us_re_kl = timeit(lambda: fused_lp_matvec_batched(
        x_pos, ys_kl, 1.5, reuse=True, divergence="kl"))
    kl_speedup = us_pb_kl / max(us_re_kl, 1e-9)
    emit(f"kernels/fused_lp_batched_reuse_kl/n={kn},b={kb},c={kc}", us_re_kl,
         f"speedup={kl_speedup:.2f}x")
    backends["kl"] = {"n": kn, "batch": kb, "c": kc,
                      "perbatch_us": us_pb_kl, "reuse_us": us_re_kl,
                      "reuse_speedup": kl_speedup}

    write_json("kernels", {
        "n": N, "batch": BATCH, "c": C,
        "perbatch_us": us_pb,
        "reuse_us": us_re,
        "fused_lp_reuse_speedup": reuse_speedup,
        "backends": backends,
        # always the full acceptance shape; never mislabeled as tiny
        "tiny": False,
    })


if __name__ == "__main__":
    run()
