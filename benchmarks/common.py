"""Benchmark utilities: timing, CSV emission, machine-readable JSON results.

Benchmarks print their CSV lines as before (`emit`) and additionally collect
key figures into a dict written as ``BENCH_<name>.json`` (`write_json`) —
the artifact the CI `bench-gate` job uploads and checks against the
committed floors in ``benchmarks/baselines.json``.  ``BENCH_OUT_DIR``
overrides where the JSON lands (default: current directory).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

from benchmarks.check_gate import SCHEMA_VERSION

__all__ = ["timeit", "emit", "json_path", "write_json", "SCHEMA_VERSION"]


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time in microseconds (blocks on device results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def json_path(name: str) -> str:
    """Where ``BENCH_<name>.json`` goes (honors ``BENCH_OUT_DIR``)."""
    return os.path.join(os.environ.get("BENCH_OUT_DIR", "."),
                        f"BENCH_{name}.json")


def write_json(name: str, payload: dict) -> str:
    """Write the benchmark's machine-readable result file; returns its path."""
    path = json_path(name)
    payload = dict(payload)
    payload.setdefault("bench", name)
    # schema stamp: check_gate refuses artifacts from older benchmark
    # revisions instead of silently passing them against newer bounds
    payload.setdefault("schema_version", SCHEMA_VERSION)
    payload.setdefault("tiny", bool(os.environ.get("BENCH_TINY")))
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {path}", flush=True)
    return path
