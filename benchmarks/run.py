"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Set BENCH_FAST=1 to run the
reduced sweep (CI); BENCH_LARGE_N scales the Table-2 surrogate.
"""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    if fast:
        os.environ.setdefault("BENCH_LARGE_N", "20000")

    from benchmarks import (ccr, construction, kernels_bench, large_scale,
                            matvec, refinement, roofline_table, serving)

    suites = [
        ("fig2a-construction", construction.run),
        ("fig2b-matvec", matvec.run),
        ("fig2c-ccr", ccr.run),
        ("fig2d-k-refinement", refinement.run),
        ("table2-large-scale", large_scale.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_table.run),
        ("serving-engine", serving.run),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
