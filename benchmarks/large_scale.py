"""Paper Table 2: very-large-scale construction + propagation.

The paper runs alpha (0.5M x 500) and ocr (3.5M x 1156) serially in
hours; this container is a single CPU core, so we run a scaled surrogate
(alpha-like, N configurable via BENCH_LARGE_N) and report measured times +
the O(N log N + |B|) model extrapolation to the paper's full sizes."""
from __future__ import annotations

import math
import os
import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.label_prop import ccr, label_propagate, one_hot_labels
from repro.core.vdt import VariationalDualTree
from repro.data.synthetic import alpha_like

N = int(os.environ.get("BENCH_LARGE_N", 100_000))
D = 64   # scaled from 500 to keep CPU runtime sane; scaling noted in derived
ITERS = 50


def run():
    rng = np.random.RandomState(0)
    x_np = alpha_like(n=N, d=D).x
    labels = alpha_like(n=N, d=D).labels
    x = jnp.asarray(x_np)

    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(x, max_blocks=2 * N, refine_batch=512,
                                  sigma_iters=3)
    us_build = (time.perf_counter() - t0) * 1e6
    emit(f"table2/build/alpha_like/n={N}", us_build,
         f"blocks={vdt.n_blocks},sigma={vdt.sigma:.3f}")

    labeled = np.zeros(N, bool)
    labeled[rng.choice(N, N // 10, replace=False)] = True
    y0 = one_hot_labels(labels, labeled, 2)
    t0 = time.perf_counter()
    yf = label_propagate(vdt.matvec, y0, 0.01, ITERS)
    yf.block_until_ready()
    us_prop = (time.perf_counter() - t0) * 1e6
    acc = ccr(yf, labels, ~labeled)
    emit(f"table2/propagate/alpha_like/n={N}/iters={ITERS}", us_prop,
         f"ccr={acc:.4f}")

    # beyond paper: BATCH concurrent propagation problems (distinct labeled
    # subsets) answered by ONE fitted tree in a single batched dispatch,
    # vs the serial loop the paper's serving model implies
    batch = 8
    y0s = []
    for b in range(batch):
        lab = np.zeros(N, bool)
        lab[rng.choice(N, N // 10, replace=False)] = True
        y0s.append(np.asarray(one_hot_labels(labels, lab, 2)))
    stack = jnp.asarray(np.stack(y0s))
    # warm both paths so neither timing window pays trace+compile
    vdt.label_propagate(stack, alpha=0.01, n_iters=ITERS).block_until_ready()
    vdt.label_propagate(stack[0], alpha=0.01,
                        n_iters=ITERS).block_until_ready()
    t0 = time.perf_counter()
    out = vdt.label_propagate(stack, alpha=0.01, n_iters=ITERS)
    out.block_until_ready()
    us_bat = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for b in range(batch):
        vdt.label_propagate(stack[b], alpha=0.01,
                            n_iters=ITERS).block_until_ready()
    us_loop = (time.perf_counter() - t0) * 1e6
    emit(f"table2/propagate_batched/alpha_like/n={N}/b={batch}", us_bat,
         f"loop={us_loop:.0f}us,speedup={us_loop / us_bat:.2f}x")

    # extrapolate to the paper's full sizes with the measured constant
    c_build = us_build / (N * math.log2(N))
    for name, n_full in (("alpha", 500_000), ("ocr", 3_500_000)):
        est = c_build * n_full * math.log2(n_full)
        emit(f"table2/extrapolated_build/{name}/n={n_full}", est,
             f"model=c*N*log2(N), c={c_build:.3f}us")


if __name__ == "__main__":
    run()
