"""CI benchmark-regression gate.

Compares the machine-readable results the benchmarks wrote
(``BENCH_<name>.json``, see ``benchmarks/common.write_json``) against the
committed bounds in ``benchmarks/baselines.json`` and exits non-zero when
any figure breaches its bound — turning the benchmark smoke into an actual
regression gate.

Baseline schema (version :data:`SCHEMA_VERSION`)
------------------------------------------------
``baselines.json`` carries a top-level ``schema_version`` plus one object
per benchmark.  Metric names are **dotted paths** resolved into the
bench's (possibly nested) JSON — e.g. ``serving.fifo.speedup`` is the
``"speedup"`` key inside the ``"fifo"`` object of ``BENCH_serving.json`` —
so per-policy / per-backend namespaces (``fifo.*``, ``edf.*``,
``backends.kl.*``) gate independently.  Each bound is either a bare number
(shorthand for ``{"min": x}``) or an object with ``min`` and/or ``max``:
``min`` floors speedups/occupancies, ``max`` caps badness metrics like
``edf.deadline_miss_rate``.

Every result file must carry the matching ``schema_version`` (stamped by
``benchmarks/common.write_json``): a stale ``BENCH_*.json`` produced by an
older benchmark revision fails LOUDLY here instead of silently passing
against bounds it never measured.

Bounds are deliberately conservative (well clear of what a quiet CI runner
measures in tiny mode) so OS noise doesn't flake the gate, while a real
regression — e.g. the priority policy degrading to FIFO tail latency —
still trips it.

    python -m benchmarks.check_gate [--dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINES = os.path.join(os.path.dirname(__file__), "baselines.json")

# bumped whenever the BENCH_*.json layout or the baseline schema changes;
# benchmarks/common.write_json stamps it into every result file
SCHEMA_VERSION = 2


def lookup(result: dict, dotted: str):
    """Resolve a dotted metric path into a (possibly nested) result dict."""
    node = result
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(results_dir: str) -> int:
    with open(BASELINES) as fh:
        baselines = json.load(fh)
    expected_schema = baselines.get("schema_version")
    if expected_schema != SCHEMA_VERSION:
        print(
            f"baselines.json schema_version {expected_schema!r} != "
            f"checker schema {SCHEMA_VERSION} — update them together",
            file=sys.stderr,
        )
        return 1

    failures, checked = [], 0
    for bench, bounds in baselines.items():
        if bench.startswith("_") or bench == "schema_version":
            continue  # annotation keys, not benchmarks
        path = os.path.join(results_dir, f"BENCH_{bench}.json")
        if not os.path.exists(path):
            failures.append(f"{bench}: missing {path} (benchmark not run?)")
            continue
        with open(path) as fh:
            result = json.load(fh)
        got_schema = result.get("schema_version")
        if got_schema != expected_schema:
            failures.append(
                f"{bench}: schema_version {got_schema!r} != expected "
                f"{expected_schema} — stale artifact from an older "
                f"benchmark revision; re-run the benchmark"
            )
            continue
        for metric, bound in bounds.items():
            if not isinstance(bound, dict):
                bound = {"min": bound}
            got = lookup(result, metric)
            if got is None:
                failures.append(f"{bench}.{metric}: not in {path}")
                continue
            checked += 1
            problems = []
            if "min" in bound and got < bound["min"]:
                problems.append(f"{got:.3f} < min {bound['min']}")
            if "max" in bound and got > bound["max"]:
                problems.append(f"{got:.3f} > max {bound['max']}")
            status = "FAIL" if problems else "OK "
            spec = ", ".join(f"{k}={v}" for k, v in sorted(bound.items()))
            print(f"[{status}] {bench}.{metric}: {got:.3f} ({spec})")
            for problem in problems:
                failures.append(f"{bench}.{metric}: {problem}")

    if failures:
        print("\nbench-gate FAILED:", file=sys.stderr)
        for failure in failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    print(f"\nbench-gate passed ({checked} metrics)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=".", help="directory holding the BENCH_*.json results")
    args = ap.parse_args()
    return check(args.dir)


if __name__ == "__main__":
    sys.exit(main())
