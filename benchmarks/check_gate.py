"""CI benchmark-regression gate.

Compares the machine-readable results the benchmarks wrote
(``BENCH_<name>.json``, see ``benchmarks/common.write_json``) against the
committed floors in ``benchmarks/baselines.json`` and exits non-zero when
any figure falls below its floor — turning the benchmark smoke into an
actual regression gate.

Baselines map ``<bench>.<metric>`` to a floor; metrics are looked up in the
bench's JSON top level (keys starting with ``_`` are annotations, skipped).
Floors are deliberately conservative (well under what a quiet CI runner
measures in tiny mode) so OS noise doesn't flake the gate, while a real
regression — e.g. the batched path degrading to the per-request loop —
still trips it.

    python -m benchmarks.check_gate [--dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINES = os.path.join(os.path.dirname(__file__), "baselines.json")


def check(results_dir: str) -> int:
    with open(BASELINES) as fh:
        baselines = json.load(fh)

    failures, checked = [], 0
    for bench, floors in baselines.items():
        if bench.startswith("_"):
            continue  # annotation keys, not benchmarks
        path = os.path.join(results_dir, f"BENCH_{bench}.json")
        if not os.path.exists(path):
            failures.append(f"{bench}: missing {path} (benchmark not run?)")
            continue
        with open(path) as fh:
            result = json.load(fh)
        for metric, floor in floors.items():
            got = result.get(metric)
            if got is None:
                failures.append(f"{bench}.{metric}: not in {path}")
                continue
            checked += 1
            status = "OK " if got >= floor else "FAIL"
            print(f"[{status}] {bench}.{metric}: {got:.3f} (floor {floor})")
            if got < floor:
                failures.append(f"{bench}.{metric}: {got:.3f} < floor {floor}")

    if failures:
        print("\nbench-gate FAILED:", file=sys.stderr)
        for failure in failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    print(f"\nbench-gate passed ({checked} metrics)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=".", help="directory holding the BENCH_*.json results")
    args = ap.parse_args()
    return check(args.dir)


if __name__ == "__main__":
    sys.exit(main())
