"""End-to-end driver (the paper's headline application): semi-supervised
learning by Label Propagation over the VDT transition matrix, compared
against the kNN and exact baselines under identical conditions (paper §5).

    PYTHONPATH=src python examples/lp_semisupervised.py [--n 20000]
"""
import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (VariationalDualTree, build_knn_graph, ccr,
                        exact_transition_matrix, knn_matvec, label_propagate,
                        one_hot_labels)
from repro.data.synthetic import digit1_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--labels-frac", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.01)
    ap.add_argument("--iters", type=int, default=500)
    args = ap.parse_args()

    data = digit1_like(n=args.n)
    x = jnp.asarray(data.x)
    rng = np.random.RandomState(0)
    labeled = np.zeros(args.n, bool)
    labeled[rng.choice(args.n, int(args.n * args.labels_frac),
                       replace=False)] = True
    y0 = one_hot_labels(data.labels, labeled, data.n_classes)

    # ---- VariationalDT ----------------------------------------------------
    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(x, max_blocks=4 * args.n, refine_batch=256)
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    yf = label_propagate(vdt.matvec, y0, args.alpha, args.iters)
    yf.block_until_ready()
    t_prop = time.perf_counter() - t0
    acc = ccr(yf, data.labels, ~labeled)
    print(f"VDT     build {t_build:7.2f}s  propagate({args.iters}) "
          f"{t_prop:7.2f}s  CCR {acc:.4f}  (|B|={vdt.n_blocks}, "
          f"sigma*={vdt.sigma:.3f})")

    # ---- kNN ---------------------------------------------------------------
    sig = jnp.asarray(vdt.sigma)
    t0 = time.perf_counter()
    g = build_knn_graph(x, 4, sig)
    g.weights.block_until_ready()
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    yf = label_propagate(lambda y: knn_matvec(g, y), y0, args.alpha, args.iters)
    yf.block_until_ready()
    t_prop = time.perf_counter() - t0
    acc = ccr(yf, data.labels, ~labeled)
    print(f"kNN(4)  build {t_build:7.2f}s  propagate({args.iters}) "
          f"{t_prop:7.2f}s  CCR {acc:.4f}")

    # ---- exact (only if it fits) -------------------------------------------
    if args.n <= 8000:
        t0 = time.perf_counter()
        p = exact_transition_matrix(x, sig)
        p.block_until_ready()
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        yf = label_propagate(lambda y: p @ y, y0, args.alpha, args.iters)
        yf.block_until_ready()
        t_prop = time.perf_counter() - t0
        acc = ccr(yf, data.labels, ~labeled)
        print(f"exact   build {t_build:7.2f}s  propagate({args.iters}) "
              f"{t_prop:7.2f}s  CCR {acc:.4f}")
    else:
        print(f"exact   skipped (N={args.n}: P would be "
              f"{args.n*args.n*4/1e9:.1f} GB)")


if __name__ == "__main__":
    main()
