"""Serve a small LM with batched requests: prefill a batch of prompts, then
decode tokens autoregressively with per-family KV/SSM caches.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m --tokens 32
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.transformer import init_lm
from repro.models.whisper import init_encdec
from repro.serving.decode import decode_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.RandomState(0)
    init_fn = init_encdec if cfg.family == "audio" else init_lm
    params = init_fn(cfg, jax.random.PRNGKey(0))

    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        kwargs["frames"] = jnp.asarray(
            rng.randn(args.batch, cfg.encoder_frames, cfg.d_model),
            jnp.float32)

    t0 = time.perf_counter()
    logits, state = jax.jit(
        lambda p, t, **kw: prefill(p, t, cfg, **kw))(params, prompts, **kwargs)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")

    step = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg))
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        logits, state = step(params, tok, state)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits / args.temperature)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    toks = np.concatenate([np.asarray(t) for t in generated], axis=1)
    print(f"decode: {args.tokens} tokens x {args.batch} seqs in {dt*1e3:.1f} ms "
          f"({args.tokens*args.batch/dt:.0f} tok/s, cache={cfg.family})")
    print("sample token ids:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
