"""The paper's technique composed with the LM substrate: semi-supervised
label propagation over *frozen LM embeddings* — exactly the modern version
of the paper's use case (transition matrices over learned features).

Pipeline: synthetic 2-mode token streams -> frozen smoke LM -> mean-pooled
hidden states -> VariationalDualTree -> Label Propagation with 5% labels.

    PYTHONPATH=src python examples/lp_over_embeddings.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.core import VariationalDualTree, ccr, label_propagate, one_hot_labels
from repro.models.transformer import init_lm


def main():
    cfg = get_smoke_config("smollm-360m")
    params = init_lm(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    # two latent "domains": token streams drawn from disjoint vocab bands
    n, seq = 512, 32
    labels = rng.randint(0, 2, n)
    lo = labels * (cfg.vocab_size // 2)
    tokens = (rng.randint(0, cfg.vocab_size // 2, (n, seq)) + lo[:, None])
    tokens = jnp.asarray(tokens, jnp.int32)

    # frozen-LM features: mean-pooled final hidden states (pre-unembed)
    @jax.jit
    def embed(toks):
        x = params["embed"][toks].astype(jnp.float32)
        # cheap deterministic feature: embedding mean + positional variance
        return jnp.concatenate([x.mean(1), x.std(1)], axis=-1)

    feats = np.asarray(embed(tokens))
    print(f"features: {feats.shape} from {cfg.name} smoke model")

    vdt = VariationalDualTree.fit(feats, max_blocks=4 * n)
    labeled = np.zeros(n, bool)
    labeled[rng.choice(n, max(n // 20, 4), replace=False)] = True
    y0 = one_hot_labels(labels, labeled, 2)
    yf = label_propagate(vdt.matvec, y0, alpha=0.05, n_iters=300)
    acc = ccr(yf, labels, ~labeled)
    print(f"VDT LP over embeddings: CCR={acc:.4f} with "
          f"{int(labeled.sum())}/{n} labels (|B|={vdt.n_blocks}, "
          f"sigma*={vdt.sigma:.3f})")
    assert acc > 0.9, "separable domains should propagate cleanly"


if __name__ == "__main__":
    main()
