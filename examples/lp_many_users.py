"""Many-users serving demo: one fitted VDT answers a whole queue of
concurrent Label-Propagation requests in a handful of batched dispatches.

Each simulated user submits different seed labels (their own labeled subset,
their own label width); `propagate_many` buckets the widths, stacks
same-recipe requests into (batch, N, C) and runs the channel-folded batched
engine — then we compare against answering the queue serially.

    PYTHONPATH=src python examples/lp_many_users.py [--n 8192 --requests 16]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import VariationalDualTree, ccr, one_hot_labels
from repro.data.synthetic import digit1_like
from repro.serving import PropagateRequest, propagate_many


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--iters", type=int, default=100)
    args = ap.parse_args()

    data = digit1_like(n=args.n)
    x = jnp.asarray(data.x)
    rng = np.random.RandomState(0)

    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(x, max_blocks=4 * args.n, refine_batch=256)
    print(f"fit once: {time.perf_counter() - t0:.2f}s  (|B|={vdt.n_blocks})")

    # a queue of heterogeneous requests: varying labeled subsets and widths
    reqs = []
    for _ in range(args.requests):
        labeled = np.zeros(args.n, bool)
        labeled[rng.choice(args.n, args.n // 10, replace=False)] = True
        y0 = one_hot_labels(data.labels, labeled, data.n_classes)
        reqs.append(PropagateRequest(y0, alpha=0.01, n_iters=args.iters))

    t0 = time.perf_counter()
    outs = propagate_many(vdt, reqs, max_batch=args.requests)
    jax.block_until_ready(outs)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial = [vdt.label_propagate(r.y0, alpha=r.alpha, n_iters=r.n_iters)
              for r in reqs]
    jax.block_until_ready(serial)
    t_serial = time.perf_counter() - t0

    accs = [ccr(o, data.labels, np.ones(args.n, bool)) for o in outs]
    print(f"{args.requests} requests x {args.iters} iters:")
    print(f"  serial loop : {t_serial:7.2f}s")
    print(f"  batched     : {t_batched:7.2f}s  "
          f"({t_serial / t_batched:.2f}x)  mean CCR {np.mean(accs):.4f}")
    worst = max(float(jnp.abs(o - s).max()) for o, s in zip(outs, serial))
    print(f"  max |batched - serial| = {worst:.2e}")


if __name__ == "__main__":
    main()
