"""Train a ~10M-param LM for a few hundred steps with full fault-tolerance
machinery (checkpoint every 50 steps, resumable, preemption-safe).

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main([
        "--arch", "smollm-360m", "--smoke",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50",
        "--lr", "3e-3",
    ]))
