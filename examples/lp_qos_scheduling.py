"""Scheduler-v2 demo: priorities, deadlines, and exact/VDT hybrid routing.

One fitted VDT, three short acts:

1. a ``policy="priority"`` engine under a low-priority backlog — watch the
   high-priority request jump the queue (and the aging bound keep the
   backlog moving);
2. a ``policy="edf"`` engine with mixed deadlines — the tight-deadline
   request dispatches first, and a request whose deadline lapses while
   queued fails fast with the pinned ``DeadlineExceeded``;
3. per-request backend routing — bulk traffic rides the fitted VDT while a
   validation request tagged ``backend="exact"`` gets the ground-truth
   eq.-3 walk from the same engine, without fragmenting the bulk batch.

    PYTHONPATH=src python examples/lp_qos_scheduling.py [--n 1024]
"""
import argparse
import time

import numpy as np

from repro.core import VariationalDualTree
from repro.serving import (DeadlineExceeded, PropagateEngine,
                           PropagateRequest)

ITERS = 30


def seeds(rng, n, c=4):
    return (rng.rand(n, c) > 0.9).astype(np.float32)


def act_priority(vdt, rng, n):
    print("\n== 1. priority policy: urgent traffic jumps a backlog ==")
    with PropagateEngine(vdt, policy="priority", max_batch=4,
                         max_wait_ms=2.0, start=False) as eng:
        bulk = [eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS))
                for _ in range(8)]
        urgent = eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS,
                                             priority=5))
        eng.step()  # first microbatch: urgent is in it despite arriving last
        print(f"   after one microbatch: urgent done={urgent.done()}, "
              f"bulk done={sum(f.done() for f in bulk)}/8")
        eng.flush()
        print(f"   after flush: bulk done={sum(f.done() for f in bulk)}/8, "
              f"policy={eng.metrics().policy}")


def act_deadlines(vdt, rng, n):
    print("\n== 2. edf policy: deadlines order the queue, expiry fails fast ==")
    with PropagateEngine(vdt, policy="edf", max_batch=2, max_wait_ms=0.0,
                         start=False) as eng:
        loose = eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS,
                                            deadline_ms=5000.0))
        tight = eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS,
                                            deadline_ms=500.0))
        doomed = eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS,
                                             deadline_ms=1.0))
        time.sleep(0.01)  # let the 1ms deadline lapse while queued
        eng.flush()
        print(f"   tight(500ms) done={tight.done()}, "
              f"loose(5s) done={loose.done()}")
        try:
            doomed.result(timeout=0)
        except DeadlineExceeded as exc:
            print(f"   doomed(1ms) fast-failed: {type(exc).__name__}: {exc}")
        m = eng.metrics()
        print(f"   metrics: completed={m.completed} expired={m.expired}")


def act_hybrid(vdt, rng, n):
    print("\n== 3. hybrid routing: exact validation inside a VDT engine ==")
    with PropagateEngine(vdt, max_batch=8, start=False) as eng:
        y0 = seeds(rng, n)
        bulk = [eng.submit(PropagateRequest(seeds(rng, n), n_iters=ITERS))
                for _ in range(3)]
        probe_vdt = eng.submit(PropagateRequest(y0, n_iters=ITERS))
        probe_exact = eng.submit(PropagateRequest(y0, n_iters=ITERS,
                                                  backend="exact"))
        eng.flush()
        for f in bulk:
            f.result(timeout=0)
        a = np.asarray(probe_vdt.result(timeout=0))
        b = np.asarray(probe_exact.result(timeout=0))
        agree = float((a.argmax(1) == b.argmax(1)).mean())
        m = eng.metrics()
        print(f"   dispatches={m.dispatches} (one VDT group + one exact "
              f"group), VDT-vs-exact argmax agreement={agree:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    x = rng.randn(args.n, 16).astype(np.float32)
    print(f"fitting VDT on N={args.n} ...")
    vdt = VariationalDualTree.fit(x, max_blocks=4 * args.n)
    print(f"fitted: |B|={vdt.n_blocks}")

    act_priority(vdt, rng, args.n)
    act_deadlines(vdt, rng, args.n)
    act_hybrid(vdt, rng, args.n)


if __name__ == "__main__":
    main()
