"""Quickstart: build a variational dual-tree transition matrix, inspect it,
run a random-walk step, and refine it — the paper's core API in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import VariationalDualTree
from repro.data.synthetic import blobs

# 1. data: 2 000 points in two Gaussian clusters
data = blobs(n=2000, d=16, n_classes=2, sep=6.0, seed=0)

# 2. fit: partition tree + coarsest block partition + learned bandwidth
vdt = VariationalDualTree.fit(data.x, max_blocks=8000)
print(f"N={len(data.x)}  blocks={vdt.n_blocks}  "
      f"sigma*={vdt.sigma:.3f}  bound={vdt.bound:.1f}")
print(f"tree: {vdt.stats.build_tree_s*1e3:.1f} ms,  "
      f"q-opt: {vdt.stats.init_qopt_s*1e3:.1f} ms,  "
      f"refine: {vdt.stats.refine_s*1e3:.1f} ms")

# 3. one random-walk step: Q @ y in O(|B|), never materializing Q
y = np.random.RandomState(0).randn(2000, 4).astype(np.float32)
y_next = vdt.matvec(y)
print("matvec ok:", np.asarray(y_next).shape)

# 4. row-stochasticity (paper eq. 16): Q @ 1 == 1
ones = np.ones((2000, 1), np.float32)
print("row sums:", float(np.asarray(vdt.matvec(ones)).min()),
      float(np.asarray(vdt.matvec(ones)).max()))

# 5. refine further (paper §4.4) — the bound can only improve
b0 = vdt.bound
vdt.refine(max_blocks=16000)
print(f"refined to {vdt.n_blocks} blocks: bound {b0:.1f} -> {vdt.bound:.1f}")
