"""Async serving demo: a PropagateEngine behind an asyncio front-end.

One fitted VDT serves a swarm of asyncio client coroutines — the shape of a
real label-propagation service (each web request: build seed labels, await
the propagated result, respond).  `PropagateEngine.submit` returns a
`concurrent.futures.Future`, so `asyncio.wrap_future` is the whole bridge;
the engine's scheduler thread keeps coalescing whatever the event loop has
in flight into batched device dispatches.

    PYTHONPATH=src python examples/lp_engine_async.py [--n 4096 --clients 16]
"""
import argparse
import asyncio
import time

import numpy as np

from repro.core import VariationalDualTree, one_hot_labels
from repro.data.synthetic import digit1_like
from repro.serving import PropagateEngine, PropagateRequest


async def client(cid, eng, data, n, n_requests, rng_seed, iters):
    """One closed-loop user: submit, await, repeat."""
    rng = np.random.RandomState(rng_seed)
    latencies = []
    for _ in range(n_requests):
        labeled = np.zeros(n, bool)
        labeled[rng.choice(n, n // 10, replace=False)] = True
        y0 = one_hot_labels(data.labels, labeled, data.n_classes)
        req = PropagateRequest(np.asarray(y0), alpha=0.01, n_iters=iters)
        t0 = time.perf_counter()
        await asyncio.wrap_future(eng.submit(req))
        latencies.append(time.perf_counter() - t0)
    return cid, latencies


async def main_async(args):
    data = digit1_like(n=args.n)
    print(f"fitting VDT on N={args.n} ...")
    t0 = time.perf_counter()
    vdt = VariationalDualTree.fit(np.asarray(data.x), max_blocks=4 * args.n,
                                  refine_batch=256)
    print(f"fit once: {time.perf_counter() - t0:.2f}s  (|B|={vdt.n_blocks})")

    with PropagateEngine(vdt, max_batch=args.clients,
                         max_wait_ms=2.0) as eng:
        eng.warmup(widths=(data.n_classes,), n_iters=(args.iters,))
        t0 = time.perf_counter()
        results = await asyncio.gather(*[
            client(cid, eng, data, args.n, args.requests_per_client,
                   100 + cid, args.iters)
            for cid in range(args.clients)
        ])
        wall = time.perf_counter() - t0
        m = eng.metrics()

    total = args.clients * args.requests_per_client
    lat = sorted(t for _, ls in results for t in ls)
    print(f"{total} requests from {args.clients} async clients "
          f"in {wall:.2f}s  ({total / wall:.1f} req/s)")
    print(f"latency p50 {lat[len(lat) // 2] * 1e3:.0f}ms  "
          f"p95 {lat[int(0.95 * (len(lat) - 1))] * 1e3:.0f}ms")
    print(f"engine: {m.dispatches} dispatches, "
          f"batch occupancy {m.batch_occupancy:.1f}, "
          f"queue_depth {m.queue_depth}, failed {m.failed}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--requests-per-client", type=int, default=4)
    ap.add_argument("--iters", type=int, default=50)
    args = ap.parse_args()
    asyncio.run(main_async(args))


if __name__ == "__main__":
    main()
