"""Deterministic synthetic surrogates for the paper's SSL benchmark data.

Real datasets (SecStr, Digit1, USPS, Pascal alpha/ocr) are unavailable
offline; these generators match their N / d / class structure so the paper's
*relative* comparisons (exact vs kNN vs VDT under identical conditions, §5)
are reproducible:

  secstr_like  — high-dim sparse binary features, 2 classes (SecStr: 83 679
                 x 315 binary)
  digit1_like  — smooth low-dim manifold embedded in 241 dims (Digit1)
  usps_like    — clustered image-like features, 2 classes (USPS subset)
  alpha_like   — 500-dim dense, 2 balanced classes (Pascal alpha)
  blobs        — generic Gaussian mixture for unit tests / scaling sweeps
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = ["SslDataset", "blobs", "digit1_like", "usps_like", "secstr_like",
           "alpha_like", "two_moons", "by_name"]


class SslDataset(NamedTuple):
    x: np.ndarray        # (N, d) float32
    labels: np.ndarray   # (N,) int64
    name: str
    n_classes: int


def blobs(n: int, d: int = 8, n_classes: int = 2, sep: float = 6.0,
          spread: float = 1.0, seed: int = 0) -> SslDataset:
    r = np.random.RandomState(seed)
    labels = r.randint(0, n_classes, size=n)
    centers = r.randn(n_classes, d) * sep
    x = centers[labels] + r.randn(n, d) * spread
    return SslDataset(x.astype(np.float32), labels.astype(np.int64),
                      f"blobs{n}", n_classes)


def two_moons(n: int, noise: float = 0.08, seed: int = 0) -> SslDataset:
    r = np.random.RandomState(seed)
    n1 = n // 2
    t1 = np.pi * r.rand(n1)
    t2 = np.pi * r.rand(n - n1)
    x1 = np.stack([np.cos(t1), np.sin(t1)], 1)
    x2 = np.stack([1 - np.cos(t2), 0.5 - np.sin(t2)], 1)
    x = np.concatenate([x1, x2]) + r.randn(n, 2) * noise
    labels = np.concatenate([np.zeros(n1), np.ones(n - n1)])
    return SslDataset(x.astype(np.float32), labels.astype(np.int64),
                      f"moons{n}", 2)


def digit1_like(n: int = 1500, d: int = 241, seed: int = 1) -> SslDataset:
    """Two concentric-loop manifolds embedded in d dims + noise (Digit1 is an
    artificial manifold dataset; graph methods reach ~0.9+ CCR on it)."""
    r = np.random.RandomState(seed)
    labels = r.randint(0, 2, size=n)
    t = r.rand(n) * 2 * np.pi
    radius = 1.0 + 1.2 * labels
    base = np.stack([np.cos(t) * radius, np.sin(t) * radius,
                     0.1 * np.sin(3 * t)], 1)
    proj = r.randn(3, d) / np.sqrt(3)
    x = base @ proj + r.randn(n, d) * 0.02
    return SslDataset(x.astype(np.float32), labels.astype(np.int64),
                      "digit1-like", 2)


def usps_like(n: int = 1500, d: int = 241, seed: int = 2) -> SslDataset:
    """Clustered, heavier-tailed features (USPS handwritten digits, 2-class)."""
    r = np.random.RandomState(seed)
    labels = r.randint(0, 2, size=n)
    n_proto = 10
    protos = r.randn(2, n_proto, d) * 3.0
    which = r.randint(0, n_proto, size=n)
    x = protos[labels, which] + r.standard_t(df=4, size=(n, d)).astype(np.float64)
    return SslDataset(x.astype(np.float32), labels.astype(np.int64),
                      "usps-like", 2)


def secstr_like(n: int = 83679, d: int = 315, seed: int = 3) -> SslDataset:
    """Sparse binary features, 2 classes (SecStr: amino-acid windows)."""
    r = np.random.RandomState(seed)
    labels = r.randint(0, 2, size=n)
    p = np.where(labels[:, None] == 0, 0.08, 0.12)
    x = (r.rand(n, d) < p).astype(np.float32)
    return SslDataset(x, labels.astype(np.int64), "secstr-like", 2)


def alpha_like(n: int = 500000, d: int = 500, seed: int = 4) -> SslDataset:
    """Pascal alpha surrogate: dense 500-dim, 2 balanced classes."""
    r = np.random.RandomState(seed)
    labels = (np.arange(n) % 2).astype(np.int64)
    r.shuffle(labels)
    mean = r.randn(2, d) * 0.8
    x = mean[labels] + r.randn(n, d).astype(np.float32)
    return SslDataset(x.astype(np.float32), labels, "alpha-like", 2)


_REGISTRY = {
    "blobs": blobs,
    "moons": two_moons,
    "digit1": digit1_like,
    "usps": usps_like,
    "secstr": secstr_like,
    "alpha": alpha_like,
}


def by_name(name: str, **kw) -> SslDataset:
    return _REGISTRY[name](**kw)
