from repro.data.pipeline import FeaturePipeline, TokenPipeline
from repro.data.synthetic import SslDataset, by_name

__all__ = ["FeaturePipeline", "SslDataset", "TokenPipeline", "by_name"]
