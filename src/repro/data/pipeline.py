"""Deterministic, resumable, shardable data pipeline.

Design requirements at 1000+ node scale:

  * **Deterministic**: batch ``t`` is a pure function of ``(seed, t)`` — any
    host can (re)compute any microbatch, which is what makes checkpoint
    restart and straggler/failure replay trivial (no data-state to persist
    beyond the integer step).
  * **Shardable**: each data-parallel replica deterministically slices its
    rows out of the global batch — the same global batch is formed no matter
    how many hosts participate, so elastic re-scaling is data-transparent.
  * **Stateless resume**: ``state = step`` — stored in the checkpoint
    manifest.

For LM training we synthesize token streams (no real corpus in the
container) with a fixed-vocab mixture process that has enough structure for
loss to fall; for VDT experiments the pipeline serves feature rows.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TokenPipeline", "FeaturePipeline"]


def _rng_for_step(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=step))


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    """Synthetic LM token stream: order-2 Markov mixture over a fixed vocab.

    ``global_batch`` rows of ``seq_len + 1`` tokens; row r of batch t is a
    pure function of (seed, t, r).  ``shard(host, n_hosts)`` views the same
    global stream.
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_modes: int = 64

    def batch(self, step: int, host: int = 0, n_hosts: int = 1) -> np.ndarray:
        assert self.global_batch % n_hosts == 0
        per = self.global_batch // n_hosts
        rng = _rng_for_step(self.seed, step * 1_000_003 + host)
        mode = rng.integers(0, self.n_modes, size=(per, 1))
        base = rng.integers(0, self.vocab_size, size=(per, self.seq_len + 1))
        # impose local structure: each mode biases toward a band of tokens
        band = (mode * (self.vocab_size // max(self.n_modes, 1))) % self.vocab_size
        width = max(self.vocab_size // 16, 2)
        biased = band + rng.integers(0, width, size=(per, self.seq_len + 1))
        pick = rng.random(size=(per, self.seq_len + 1)) < 0.8
        toks = np.where(pick, biased % self.vocab_size, base)
        return toks.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class FeaturePipeline:
    """Streaming feature rows for VDT-scale experiments (blocks of rows)."""

    n_total: int
    dim: int
    seed: int = 0
    n_classes: int = 2

    def block(self, start: int, count: int) -> tuple[np.ndarray, np.ndarray]:
        rng = _rng_for_step(self.seed, start)
        labels = rng.integers(0, self.n_classes, size=count)
        centers = np.random.RandomState(self.seed).randn(self.n_classes, self.dim) * 5
        x = centers[labels] + rng.normal(size=(count, self.dim))
        return x.astype(np.float32), labels.astype(np.int64)
