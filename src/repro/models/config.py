"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention pattern -------------------------------------------------
    sliding_window: Optional[int] = None   # SWA width (mixtral, gemma3 local)
    local_global_ratio: int = 0            # gemma3: 5 local : 1 global
    rope_theta: float = 10_000.0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None         # routed-expert hidden width
    capacity_factor: float = 1.25
    expert_parallel: bool = False          # EP (shard experts) vs expert-TP

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2): shared attention block every k ssm layers ----------
    attn_every: int = 0

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_frames: int = 1500             # stub frontend sequence length

    # --- vlm (internvl): stub patch embeddings prepended ---------------------
    n_patches: int = 0

    # --- numerics / compile --------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    vocab_pad_to: int = 256
    tie_embeddings: bool = False
    # unroll the layer scan — identical math/HLO semantics, but XLA's cost
    # analysis counts while-loop bodies once; the dry-run compiles an
    # unrolled twin of each cell to obtain trip-count-true FLOPs/bytes.
    scan_unroll: bool = False

    # ------------------------------------------------------------------ props
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM, hybrid, or pure sliding-window."""
        if self.family in ("ssm", "hybrid"):
            return True
        # pure SWA (no global layers): mixtral
        return self.sliding_window is not None and self.local_global_ratio == 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have an autoregressive side

    def layer_is_global(self, i: int) -> bool:
        """gemma3-style local:global interleave — every (ratio+1)-th global."""
        if self.local_global_ratio <= 0:
            return self.sliding_window is None
        return (i + 1) % (self.local_global_ratio + 1) == 0

    def layer_is_attn(self, i: int) -> bool:
        """hybrid: which layers run the shared attention block."""
        return self.attn_every > 0 and (i + 1) % self.attn_every == 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd, hq, hkv = self.head_dim_, self.n_heads, self.n_kv_heads
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "hybrid"):
            di, ns = self.d_inner, self.ssm_state
            g = self.ssm_groups
            per = (d * (2 * di + 2 * g * ns + self.ssm_heads)
                   + di * d + 3 * self.ssm_heads
                   + self.ssm_conv * (di + 2 * g * ns))
            n += self.n_layers * per
            if self.attn_every:
                n += (d * hd * (hq + 2 * hkv) + hq * hd * d) + 3 * d * f
        else:
            attn = d * hd * (hq + 2 * hkv) + hq * hd * d
            if self.n_experts:
                fe = self.moe_d_ff or f
                mlp = (self.n_experts + self.n_shared_experts) * 3 * d * fe
                mlp += d * self.n_experts  # router
            else:
                mlp = 3 * d * f
            n += self.n_layers * (attn + mlp)
            if self.is_encoder_decoder:
                n += self.n_encoder_layers * (attn + 3 * d * f)
                n += self.n_layers * attn  # cross attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        fe = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * fe
        return self.param_count() - self.n_layers * inactive
