"""Mixture-of-Experts layer: token-choice top-k routing with static-shape
capacity dispatch (TPU-friendly — no ragged tensors, no host sync).

Dispatch: flatten (token, expert-choice) assignments, group by expert with a
stable argsort, compute each assignment's slot inside its expert via
``searchsorted`` group starts, drop beyond-capacity assignments, and gather
tokens into an (E, C, D) buffer.  Expert FFNs run as one batched einsum whose
expert dimension is sharded over the ``model`` mesh axis when
``cfg.expert_parallel`` (deepseek: 64 experts / 16 shards -> EP + all-to-all
from GSPMD); otherwise experts are replicated and ``d_ff`` is sharded
(mixtral: 8 experts < 16 shards -> expert tensor parallelism).

Aux load-balance loss (Switch-style): mean(fraction_tokens_e * mean_prob_e) * E.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg):
    d = cfg.d_model
    fe = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(kr, d, e, scale=0.02),
        "w_gate": jax.random.normal(k1, (e, d, fe), jnp.float32) * d ** -0.5,
        "w_up": jax.random.normal(k2, (e, d, fe), jnp.float32) * d ** -0.5,
        "w_down": jax.random.normal(k3, (e, fe, d), jnp.float32) * fe ** -0.5,
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        g1, g2, g3 = jax.random.split(ks, 3)
        params["shared"] = {
            "w_gate": dense_init(g1, d, fs),
            "w_up": dense_init(g2, d, fs),
            "w_down": dense_init(g3, fs, d, scale=fs ** -0.5),
        }
    return params


def _dispatch_indices(top_i: jax.Array, n_experts: int, capacity: int):
    """top_i: (T, k) expert choices.  Returns (table, valid):
    table (E, C) holds flat assignment indices into (T*k,), sentinel T*k."""
    t, k = top_i.shape
    flat_e = top_i.reshape(-1)                        # (T*k,)
    order = jnp.argsort(flat_e, stable=True)          # group by expert
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))  # (E,)
    pos = jnp.arange(t * k) - starts[sorted_e]        # slot within expert
    keep = pos < capacity
    dest = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)
    table = jnp.full((n_experts * capacity + 1,), t * k, jnp.int32)
    table = table.at[dest].set(order.astype(jnp.int32), mode="drop")
    table = table[:-1].reshape(n_experts, capacity)
    valid = table < t * k
    return table, valid


def moe_apply(params, x: jax.Array, cfg, compute_dtype):
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    xt = x.reshape(t, d)

    logits = (xt @ params["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)            # (T, E)
    top_p, top_i = jax.lax.top_k(probs, k)             # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = int(cfg.capacity_factor * t * k / e) + 1
    table, valid = _dispatch_indices(top_i, e, capacity)

    # gather tokens into expert buffers: (E, C, D)
    tok_of = jnp.where(valid, table // k, t)           # sentinel row t
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xt_pad[tok_of].astype(compute_dtype)

    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)             # (E, C, D)

    # combine: scatter back with routing weights
    wslot = jnp.where(
        valid,
        jnp.take(top_p.reshape(-1), jnp.minimum(table, t * k - 1)),
        0.0,
    ).astype(compute_dtype)
    y = jnp.zeros((t + 1, d), compute_dtype).at[tok_of].add(ye * wslot[..., None])
    y = y[:t]

    if cfg.n_shared_experts:
        sp = params["shared"]
        hg = jax.nn.silu(xt.astype(compute_dtype) @ sp["w_gate"].astype(compute_dtype))
        hu = xt.astype(compute_dtype) @ sp["w_up"].astype(compute_dtype)
        y = y + (hg * hu) @ sp["w_down"].astype(compute_dtype)

    # Switch-style load-balance aux loss
    frac = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    imp = probs.mean(0)
    aux = (frac * imp).sum() * e

    return y.reshape(b, s, d), aux
