"""Decoder-only LM assembly covering the dense / moe / ssm / hybrid / vlm
families.  Layers are scanned (stacked params) for O(1) HLO size; per-layer
heterogeneity (gemma3 local:global windows, zamba2 shared-attention points)
is expressed as scanned per-layer scalars + ``lax.cond``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.attention import attn_apply, attn_init
from repro.models.layers import Dtypes, dense_init, mlp_apply, mlp_init, rms_norm
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssm_apply, ssm_init

__all__ = ["init_lm", "lm_forward", "layer_windows", "HUGE_WINDOW"]

HUGE_WINDOW = 1 << 30  # "no window": (qi - kj) < 2^30 is always true


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(key, cfg):
    if cfg.family == "ssm":
        k1, k2 = jax.random.split(key)
        return {"ln": jnp.zeros((cfg.d_model,)), "ssm": ssm_init(k1, cfg)}
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(key)
        return {"ln": jnp.zeros((cfg.d_model,)), "ssm": ssm_init(k1, cfg)}
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
        "attn": attn_init(k1, cfg),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff)
    return p


def init_lm(cfg, key):
    ke, ku, kl, ks = jax.random.split(key, 4)
    vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": jax.random.normal(ke, (vp, d), jnp.float32) * d ** -0.5,
        "final_ln": jnp.zeros((d,)),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(
            jax.random.split(kl, cfg.n_layers)
        ),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ku, d, vp)
    if cfg.family == "hybrid":
        a1, a2, a3 = jax.random.split(ks, 3)
        params["shared_attn"] = {
            "ln1": jnp.zeros((d,)),
            "ln2": jnp.zeros((d,)),
            "attn": attn_init(a1, cfg),
            "mlp": mlp_init(a2, d, cfg.d_ff),
        }
    return params


def layer_windows(cfg) -> jnp.ndarray:
    """Per-layer attention window (HUGE = full causal)."""
    win = []
    for i in range(cfg.n_layers):
        if cfg.local_global_ratio > 0:
            win.append(HUGE_WINDOW if cfg.layer_is_global(i)
                       else cfg.sliding_window)
        elif cfg.sliding_window is not None:
            win.append(cfg.sliding_window)
        else:
            win.append(HUGE_WINDOW)
    return jnp.asarray(win, jnp.int32)


def attn_flags(cfg) -> jnp.ndarray:
    """Per-layer flag: apply the shared attention block (hybrid)."""
    return jnp.asarray(
        [1 if cfg.layer_is_attn(i) else 0 for i in range(cfg.n_layers)], jnp.int32
    )


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _shared_block(sp, x, cfg, positions):
    a = attn_apply(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps), cfg,
                   positions, window=jnp.int32(
                       cfg.sliding_window if cfg.sliding_window else HUGE_WINDOW))
    x = x + shard_act(a, "btd")
    m = mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps), x.dtype)
    return x + shard_act(m, "btd")


def lm_forward(
    params,
    tokens: jax.Array,                     # (B, S_text)
    cfg,
    patches: Optional[jax.Array] = None,   # (B, P, D) vlm stub embeddings
):
    """Full-sequence forward; returns (logits (B, S, Vp), aux_loss)."""
    dt = Dtypes.compute(cfg)
    emb = params["embed"]
    x = emb[tokens].astype(dt)
    if patches is not None:
        x = jnp.concatenate([patches.astype(dt), x], axis=1)
    x = shard_act(x, "btd")
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    windows = layer_windows(cfg)
    flags = attn_flags(cfg)
    shared = params.get("shared_attn")

    def body(carry, scanned):
        x, aux = carry
        lp, w, flag = scanned
        if cfg.family in ("ssm", "hybrid"):
            h = ssm_apply(lp["ssm"], rms_norm(x, lp["ln"], cfg.norm_eps), cfg, dt)
            x = x + shard_act(h, "btd")
            if cfg.family == "hybrid":
                x = jax.lax.cond(
                    flag > 0,
                    lambda v: _shared_block(shared, v, cfg, positions),
                    lambda v: v,
                    x,
                )
        else:
            a = attn_apply(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                           cfg, positions, window=w)
            x = x + shard_act(a, "btd")
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                m, aux_l = moe_apply(lp["moe"], h, cfg, dt)
                aux = aux + aux_l
            else:
                m = mlp_apply(lp["mlp"], h, dt)
            x = x + shard_act(m, "btd")
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], windows, flags), unroll=cfg.scan_unroll or 1,
    )

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unemb = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = x @ unemb.astype(dt)
    return shard_act(logits, "btv"), aux
