"""Common neural layers: RMSNorm, RoPE, gated MLP, initializers.

Pure JAX: params are nested dicts of arrays; every layer is an
``init(key, ...) -> params`` plus an ``apply(params, x, ...) -> y`` pair.
Compute dtype is configurable (bf16 on TPU); params are stored f32 and cast
at use (mixed precision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "rms_norm", "rope", "mlp_init", "mlp_apply", "Dtypes"]


class Dtypes:
    @staticmethod
    def compute(cfg) -> jnp.dtype:
        return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    """Truncated-normal fan-in init, stored f32."""
    s = scale if scale is not None else d_in ** -0.5
    return jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32) * s


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in f32 for stability, cast back to input dtype."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def rope(q: jax.Array, k: jax.Array, positions: jax.Array, theta: float):
    """Rotary embeddings.  q: (B,S,Hq,D), k: (B,S,Hk,D), positions: (B,S)."""
    d = q.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        out = jnp.concatenate(
            [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
        )
        return out.astype(x.dtype)

    return rot(q), rot(k)


def mlp_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model, scale=d_ff ** -0.5),
    }


def mlp_apply(params, x: jax.Array, compute_dtype) -> jax.Array:
    """Gated SiLU MLP (llama-style)."""
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd
