"""Grouped-query attention with causal / sliding-window / bidirectional
masks, RoPE, and a KV-cache decode path (full cache or SWA ring buffer)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_attn_logits
from repro.models.layers import dense_init, rope

__all__ = ["attn_init", "attn_apply", "attn_decode", "KVCache"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    k: jax.Array        # (B, W, Hkv, D) — W = cache window (<= full seq)
    v: jax.Array        # (B, W, Hkv, D)
    pos: jax.Array      # () int32 — absolute position of next token
    # static: ring buffer (SWA, O(window) memory) vs linear cache
    ring: bool = dataclasses.field(metadata=dict(static=True), default=False)


def attn_init(key, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "w_q": dense_init(kq, d, hq * hd),
        "w_k": dense_init(kk, d, hkv * hd),
        "w_v": dense_init(kv, d, hkv * hd),
        "w_o": dense_init(ko, hq * hd, d, scale=(hq * hd) ** -0.5),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _mask(sq: int, skv: int, q_offset, causal: bool, window: Optional[jax.Array]):
    """(sq, skv) boolean mask. ``window`` may be a traced scalar (local:global
    interleave inside scan-over-layers)."""
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= kj <= qi
    if window is not None:
        m &= (qi - kj) < window
    return m


def attn_apply(
    params,
    x: jax.Array,                      # (B, S, D)
    cfg,
    positions: jax.Array,              # (B, S)
    causal: bool = True,
    window: Optional[jax.Array] = None,  # traced or static SWA width
    kv_x: Optional[jax.Array] = None,  # cross-attention source (B, Skv, D)
    use_rope: bool = True,
) -> jax.Array:
    dt = x.dtype
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    src = x if kv_x is None else kv_x
    q = _split_heads(x @ params["w_q"].astype(dt), hq, hd)
    k = _split_heads(src @ params["w_k"].astype(dt), hkv, hd)
    v = _split_heads(src @ params["w_v"].astype(dt), hkv, hd)
    if use_rope and kv_x is None:
        q, k = rope(q, k, positions, cfg.rope_theta)
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
    logits = shard_attn_logits(logits)
    if kv_x is None:
        m = _mask(x.shape[1], src.shape[1], 0, causal, window)
        logits = jnp.where(m[None, None], logits, jnp.finfo(logits.dtype).min)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(x.shape[0], x.shape[1], hq * hd)
    return o @ params["w_o"].astype(dt)


def init_cache(cfg, batch: int, max_len: int, dtype) -> KVCache:
    """Cache window: full seq for global attention, ring of ``sliding_window``
    for pure-SWA archs (mixtral) — O(window) memory regardless of context."""
    ring = cfg.sliding_window is not None and cfg.local_global_ratio == 0
    w = min(max_len, cfg.sliding_window) if ring else max_len
    shape = (batch, w, cfg.n_kv_heads, cfg.head_dim_)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32), ring=ring,
    )


def attn_decode(
    params,
    x: jax.Array,                      # (B, 1, D) — single new token
    cache: KVCache,
    cfg,
    window: Optional[jax.Array] = None,
):
    """One decode step against the cache; returns (out, new_cache)."""
    dt = x.dtype
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    b = x.shape[0]
    q = _split_heads(x @ params["w_q"].astype(dt), hq, hd)
    k_new = _split_heads(x @ params["w_k"].astype(dt), hkv, hd)
    v_new = _split_heads(x @ params["w_v"].astype(dt), hkv, hd)
    pos = jnp.broadcast_to(cache.pos[None, None], (b, 1))
    q, k_new = rope(q, k_new, pos, cfg.rope_theta)

    w = cache.k.shape[1]
    slot = cache.pos % w if cache.ring else jnp.minimum(cache.pos, w - 1)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)

    kk = _repeat_kv(k, hq // hkv)
    vv = _repeat_kv(v, hq // hkv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / (hd ** 0.5)

    # valid positions: absolute index of each cache slot <= pos, within window
    idx = jnp.arange(w)
    if cache.ring:
        base = cache.pos - (cache.pos % w)
        abs_idx = jnp.where(idx <= (cache.pos % w), base + idx, base - w + idx)
    else:
        abs_idx = idx
    valid = (abs_idx <= cache.pos) & (abs_idx >= 0)
    if window is not None:
        valid &= (cache.pos - abs_idx) < window
    logits = jnp.where(valid[None, None, None, :], logits,
                       jnp.finfo(logits.dtype).min)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(dt)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(b, 1, hq * hd)
    out = o @ params["w_o"].astype(dt)
    return out, KVCache(k=k, v=v, pos=cache.pos + 1, ring=cache.ring)
