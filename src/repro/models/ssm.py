"""Mamba2 (SSD — state-space duality) block: chunked quadratic-within /
recurrent-across scan for training and prefill, O(1)-per-token recurrent
update for decode (arXiv:2405.21060).

Layout per layer:
  in_proj : D -> [z (Di), x (Di), B (G*N), C (G*N), dt (H)]
  conv1d  : causal depthwise (kernel K) over the (x, B, C) channels
  SSD     : h' = exp(dt*A) h + dt * B x ;  y = C h + D_skip * x
  out_proj: Di -> D                         (gated by silu(z))

Di = expand * D, H = Di / head_dim, G = ssm_groups, N = ssm_state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

__all__ = ["ssm_init", "ssm_apply", "ssm_decode", "SSMCache", "init_ssm_cache"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SSMCache:
    conv: jax.Array    # (B, K-1, conv_channels) last inputs for causal conv
    state: jax.Array   # (B, H, P, N) recurrent SSM state


def ssm_init(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj": dense_init(k1, d, d_in_proj),
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_ch), jnp.float32)
        * (cfg.ssm_conv * conv_ch) ** -0.5,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jax.random.uniform(
            k3, (h,), jnp.float32, jnp.log(1e-3), jnp.log(1e-1)
        ),
        "out_proj": dense_init(k4, di, d, scale=di ** -0.5),
    }


def _split_proj(cfg, proj):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * g * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence: xbc (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[i, j] = sum_{j<k<=i} a_k."""
    s = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((s, s), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)     positive step sizes
    a: jax.Array,      # (H,)          negative decay rates
    bmat: jax.Array,   # (B, S, G, N)
    cmat: jax.Array,   # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N) initial state
):
    """Chunked SSD scan; returns (y (B,S,H,P), final_state)."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h)
    br = jnp.repeat(bmat.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,l,h,n)
    cr = jnp.repeat(cmat.reshape(b, nc, chunk, g, n), rep, axis=3)

    da = dtr * a[None, None, None, :]          # (b, nc, l, h) log-decay
    da_cum = jnp.cumsum(da, axis=2)            # within-chunk cumulative
    da_tot = da_cum[:, :, -1, :]               # (b, nc, h)

    # --- intra-chunk (quadratic, attention-like with decay kernel) ---------
    ell = jnp.exp(_segsum(jnp.swapaxes(da, 2, 3)))      # (b, nc, h, l, l)
    scores = jnp.einsum("bclhn,bcshn->bchls", cr, br)   # (b,nc,h,l,l)
    y_diag = jnp.einsum("bchls,bchls,bcshp,bcsh->bclhp",
                        scores, ell, xr, dtr)

    # --- chunk states -------------------------------------------------------
    decay_states = jnp.exp(da_tot[:, :, None, :] - da_cum)      # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        br, decay_states, dtr, xr)              # (b,nc,h,p,n)

    # --- inter-chunk recurrence over chunk boundary states -----------------
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)

    def step(carry, inp):
        st, dtot = inp                                   # (b,h,p,n), (b,h)
        new = carry * jnp.exp(dtot)[:, :, None, None] + st
        return new, carry                                # emit PREVIOUS state

    states_t = jnp.moveaxis(states, 1, 0)                # (nc, b, h, p, n)
    datot_t = jnp.moveaxis(da_tot, 1, 0)                 # (nc, b, h)
    final, prev_states = jax.lax.scan(step, h0, (states_t, datot_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b, nc, h, p, n)

    # --- inter-chunk output contribution ------------------------------------
    state_decay = jnp.exp(da_cum)                        # (b, nc, l, h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       cr, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_apply(params, x: jax.Array, cfg, compute_dtype,
              h0: Optional[jax.Array] = None, return_state: bool = False):
    """Full-sequence SSD block: x (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    proj = x @ params["in_proj"].astype(compute_dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, params["conv_w"].astype(compute_dtype),
                       params["conv_b"].astype(compute_dtype))
    xs = xbc[..., :di].reshape(b, s, h, p)
    bmat = xbc[..., di : di + g * n].reshape(b, s, g, n)
    cmat = xbc[..., di + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    y, hf = ssd_chunked(
        xs.astype(jnp.float32), dt, a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32),
        chunk=min(cfg.ssm_chunk, s), h0=h0,
    )
    y = y.astype(compute_dtype)
    y = y + xs * params["d_skip"].astype(compute_dtype)[None, None, :, None]
    y = y.reshape(b, s, di) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(compute_dtype)
    if return_state:
        return out, hf
    return out


def init_ssm_cache(cfg, batch: int, dtype) -> SSMCache:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    )


def ssm_decode(params, x: jax.Array, cache: SSMCache, cfg, compute_dtype):
    """One-token recurrent update: x (B, 1, D) -> (out, new_cache). O(1)/token."""
    b = x.shape[0]
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    proj = x @ params["in_proj"].astype(compute_dtype)
    z, xbc, dt = _split_proj(cfg, proj)

    # causal conv against the cached window
    win = jnp.concatenate([cache.conv, xbc], axis=1)     # (B, K, C)
    w = params["conv_w"].astype(compute_dtype)
    conv_out = (win * w[None]).sum(axis=1, keepdims=True)
    xbc1 = jax.nn.silu(conv_out + params["conv_b"].astype(compute_dtype))

    xs = xbc1[..., :di].reshape(b, h, p)
    bmat = jnp.repeat(xbc1[..., di : di + g * n].reshape(b, g, n), h // g, axis=1)
    cmat = jnp.repeat(xbc1[..., di + g * n :].reshape(b, g, n), h // g, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    decay = jnp.exp(dt * a[None, :])                     # (B, H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bmat.astype(jnp.float32),
                     xs.astype(jnp.float32))
    state = cache.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", cmat.astype(jnp.float32), state)
    y = y.astype(compute_dtype) + xs * params["d_skip"].astype(compute_dtype)[None, :, None]
    y = y.reshape(b, 1, di) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(compute_dtype)
    return out, SSMCache(conv=win[:, 1:], state=state)
