"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, D).  Encoder layers are
bidirectional self-attention + MLP; decoder layers are causal self-attention
+ cross-attention + MLP.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.attention import attn_apply, attn_init
from repro.models.layers import Dtypes, dense_init, mlp_apply, mlp_init, rms_norm

__all__ = ["init_encdec", "encoder_forward", "decoder_forward", "encdec_forward"]


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
        "attn": attn_init(k1, cfg),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "ln_x": jnp.zeros((cfg.d_model,)),
        "ln2": jnp.zeros((cfg.d_model,)),
        "attn": attn_init(k1, cfg),
        "xattn": attn_init(k3, cfg),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff),
    }


def init_encdec(cfg, key):
    ke, ku, kenc, kdec = jax.random.split(key, 4)
    vp, d = cfg.padded_vocab, cfg.d_model
    return {
        "embed": jax.random.normal(ke, (vp, d), jnp.float32) * d ** -0.5,
        "unembed": dense_init(ku, d, vp),
        "enc_pos": jax.random.normal(kenc, (cfg.encoder_frames, d),
                                     jnp.float32) * 0.02,
        "final_ln": jnp.zeros((d,)),
        "enc_final_ln": jnp.zeros((d,)),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(
            jax.random.split(kenc, cfg.n_encoder_layers)
        ),
        "layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(
            jax.random.split(kdec, cfg.n_layers)
        ),
    }


def encoder_forward(params, frames: jax.Array, cfg):
    """frames: (B, T_enc, D) stub embeddings -> (B, T_enc, D)."""
    dt = Dtypes.compute(cfg)
    x = (frames + params["enc_pos"][None, : frames.shape[1]]).astype(dt)
    x = shard_act(x, "btd")
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        a = attn_apply(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                       pos, causal=False, use_rope=False)
        x = x + shard_act(a, "btd")
        m = mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), dt)
        return x + shard_act(m, "btd"), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                       unroll=cfg.scan_unroll or 1)
    return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def decoder_forward(params, tokens: jax.Array, enc_out: jax.Array, cfg):
    """tokens: (B, S); enc_out: (B, T_enc, D) -> logits (B, S, Vp)."""
    dt = Dtypes.compute(cfg)
    x = params["embed"][tokens].astype(dt)
    x = shard_act(x, "btd")
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    enc_out = enc_out.astype(dt)

    def body(x, lp):
        a = attn_apply(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, pos)
        x = x + shard_act(a, "btd")
        c = attn_apply(lp["xattn"], rms_norm(x, lp["ln_x"], cfg.norm_eps), cfg,
                       pos, kv_x=enc_out, use_rope=False)
        x = x + shard_act(c, "btd")
        m = mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), dt)
        return x + shard_act(m, "btd"), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["layers"],
                       unroll=cfg.scan_unroll or 1)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt)
    return shard_act(logits, "btv")


def encdec_forward(params, tokens: jax.Array, frames: jax.Array, cfg):
    enc = encoder_forward(params, frames, cfg)
    return decoder_forward(params, tokens, enc, cfg), jnp.zeros((), jnp.float32)
