"""Pipeline parallelism over the ``pod`` axis (GPipe-style schedule).

Multi-pod default maps ``pod`` to outer data parallelism; this module is the
alternative: layers are split into ``n_stages`` contiguous stages, the global
batch into ``n_micro`` microbatches, and stages execute the classic pipelined
schedule expressed as a ``shard_map`` over the pod axis with
``jax.lax.ppermute`` moving activations stage->stage.  Bubble fraction is
(S-1)/(M+S-1); the §Perf log discusses when PP beats pod-level DP (it wins
when the DCN gradient all-reduce dominates, i.e. large models on few pods).

This is a reference implementation validated on CPU meshes in
tests/test_distributed.py (2 stages x small transformer); the dry-run keeps
pod=DP as its default.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_forward"]


def pipeline_forward(
    stage_fn: Callable,      # (stage_params, x, stage_idx) -> x
    stage_params,            # pytree stacked over stages on axis 0
    x: jax.Array,            # (n_micro, micro_batch, ...) microbatched input
    mesh: Mesh,
    axis: str = "pod",
):
    """GPipe forward over ``axis``.  Each device along ``axis`` holds one
    stage's params; activations flow via ppermute.  Returns final-stage
    outputs for all microbatches (on the last stage's shard)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    steps = n_micro + n_stages - 1

    def body(params_local, x_local):
        # params_local: this stage's shard — leading stage dim is 1; strip it
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)
        # x_local: (n_micro, mb, ...) — only stage 0 reads it
        stage = jax.lax.axis_index(axis)

        def step(carry, t):
            acts, outs = carry
            # stage 0 injects microbatch t (if any left), others use incoming
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where(stage == 0, x_local[inject], acts)
            y = stage_fn(params_local, x_in, stage)
            # shift activations to the next stage
            acts_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = t - (n_stages - 1)
            outs = jax.lax.cond(
                (stage == n_stages - 1) & (emit_idx >= 0),
                lambda o: o.at[jnp.maximum(emit_idx, 0)].set(y),
                lambda o: o,
                outs,
            )
            return (acts_next, outs), None

        acts0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros((n_micro,) + x_local.shape[1:], x_local.dtype)
        (_, outs), _ = jax.lax.scan(step, (acts0, outs0), jnp.arange(steps))
        # only the last stage holds outputs; replicate via psum
        return jax.lax.psum(outs, axis)

    from jax.experimental.shard_map import shard_map

    spec_params = P(axis)  # stage dim sharded across pods
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P()),       # input replicated; stage params split
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x)
