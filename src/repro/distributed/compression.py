"""Gradient compression for cross-pod (DCN) all-reduce.

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; the
standard mitigations implemented here:

  * ``bf16_compress``    — cast f32 grads to bf16 before the reduce, restore
    after (2x traffic cut; safe for grads with loss scaling).
  * ``int8_compress``    — per-tensor symmetric int8 with stochastic
    rounding (4x cut).  Stochastic rounding keeps E[deq(q(g))] = g so SGD
    remains unbiased — the property test checks both bound and bias.

These run *around* the harness's psum: compress -> all-reduce -> decompress.
Inside pjit the all-reduce is GSPMD-inserted, so the hook is applied to the
gradient pytree before the optimizer (the reduce then happens in the low
precision).  EXPERIMENTS.md §Perf quantifies the collective-term cut on the
multi-pod mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["bf16_compress", "bf16_decompress", "int8_compress",
           "int8_decompress", "compress_tree", "decompress_tree"]


def bf16_compress(g: jax.Array) -> jax.Array:
    return g.astype(jnp.bfloat16)


def bf16_decompress(g: jax.Array) -> jax.Array:
    return g.astype(jnp.float32)


def int8_compress(g: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 with stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    floor = jnp.floor(scaled)
    frac = scaled - floor
    rnd = jax.random.uniform(key, g.shape)
    q = floor + (rnd < frac).astype(scaled.dtype)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, mode: str, key=None):
    if mode == "none":
        return grads, None
    if mode == "bf16":
        return jax.tree_util.tree_map(bf16_compress, grads), None
    if mode == "int8":
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        keys = jax.random.split(key, len(leaves))
        qs, scales = zip(*(int8_compress(leaf, k)
                           for leaf, k in zip(leaves, keys)))
        return (jax.tree_util.tree_unflatten(treedef, qs),
                jax.tree_util.tree_unflatten(treedef, scales))
    raise ValueError(mode)


def decompress_tree(grads, aux, mode: str):
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree_util.tree_map(bf16_decompress, grads)
    if mode == "int8":
        return jax.tree_util.tree_map(int8_decompress, grads, aux)
    raise ValueError(mode)
