"""Sharding rules: 2-D parameter sharding (FSDP x TP), activation
constraints, and per-family overrides (EP for fine-grained MoE).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  ``pod`` is outer data-parallelism (DCN); ``data`` is FSDP;
``model`` is tensor/expert parallelism (ICI).

Model code never names mesh axes directly — it calls ``shard_act(x, kind)``
which looks up the active :class:`ShardCtx` (a no-op outside a mesh), so the
same model runs on 1 CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "use_ctx", "shard_act", "param_shardings",
           "current_ctx", "leaf_mesh", "leaf_sharding"]

_tls = threading.local()


# ---------------------------------------------------------------- VDT serving
# The sharded serving engine (serving/_sharded.py) partitions LEAF-ORDER
# arrays — label stacks (n_leaves, K), the leaf mask — row-wise over a 1-D
# device mesh.  A complete perfect-binary-tree level always has a
# power-of-two row count, so a power-of-two device count divides it evenly
# and every device owns one aligned subtree of the partition tree.

LEAF_AXIS = "leaves"


def leaf_mesh(devices=None, *, axis: str = LEAF_AXIS) -> Mesh:
    """1-D mesh over ``devices`` (default: all) for leaf-order partitioning.

    The device count must be a power of two: each device then owns a
    whole subtree of the (perfect binary) partition tree, which is what
    makes the sharded CollectUp/DistributeDown decomposition exact.
    """
    import numpy as np

    devs = list(jax.devices()) if devices is None else list(devices)
    n = len(devs)
    if n < 1 or n & (n - 1):
        raise ValueError(
            f"leaf_mesh wants a power-of-two device count, got {n}")
    return Mesh(np.array(devs), axis_names=(axis,))


def leaf_sharding(mesh: Mesh, *, axis: str = LEAF_AXIS) -> NamedSharding:
    """Row-sharded ``NamedSharding`` for leaf-order ``(n_leaves, K)`` arrays."""
    return NamedSharding(mesh, P(axis, None))


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp: Tuple[str, ...] = ("data",)       # batch / FSDP axes
    tp: str = "model"                     # tensor-parallel axis
    seq_shard: bool = False               # sequence parallelism for long ctx
    fsdp: bool = True                     # shard params over dp too
    # §Perf opt A: when n_heads % tp_size != 0 GSPMD replicates the S^2
    # attention einsums across the model axis (measured 16x waste on
    # smollm/gemma3); this switches those einsums to query-sequence sharding.
    attn_seq_shard: bool = False

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp]


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_ctx(ctx: Optional[ShardCtx]):
    prev = current_ctx()
    _tls.ctx = ctx
    try:
        yield
    finally:
        _tls.ctx = prev


_ACT_SPECS = {
    # kind -> fn(ctx) -> PartitionSpec
    "btd": lambda c: P(c.dp_spec, c.tp if c.seq_shard else None, None),
    "btv": lambda c: P(c.dp_spec, None, c.tp),          # logits: vocab sharded
    "bthd": lambda c: P(c.dp_spec, None, c.tp, None),   # heads sharded
    "btf": lambda c: P(c.dp_spec, None, c.tp),          # mlp hidden
    "bd": lambda c: P(c.dp_spec, None),
    "cache": lambda c: P(c.dp_spec, None, c.tp, None),  # (B, W, Hkv, D)
    "cache_seq": lambda c: P(c.dp_spec, c.tp, None, None),  # few kv heads
    "ecd": lambda c: P(c.tp, None, None),               # EP expert buffers
}


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    """Apply a named activation constraint if a mesh context is active."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = _ACT_SPECS[kind](ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def shard_attn_logits(logits: jax.Array) -> jax.Array:
    """(B, H, Sq, Sk) attention scores: heads over tp when divisible, else
    query-sequence over tp (opt A — avoids replicated S^2 compute)."""
    ctx = current_ctx()
    if ctx is None or not ctx.attn_seq_shard:
        return x_noop(logits)
    h = logits.shape[1]
    if h % ctx.tp_size == 0:
        spec = P(ctx.dp_spec, ctx.tp, None, None)
    else:
        spec = P(ctx.dp_spec, None, ctx.tp, None)
    return jax.lax.with_sharding_constraint(
        logits, NamedSharding(ctx.mesh, spec))


def x_noop(x):
    return x


# --------------------------------------------------------------------------
# parameter shardings, by path-name rules
# --------------------------------------------------------------------------

def _spec_for(path: str, shape: Tuple[int, ...], ctx: ShardCtx,
              expert_parallel: bool) -> P:
    fsdp = ctx.dp_spec if ctx.fsdp else None
    tp = ctx.tp
    name = path.split("/")[-1]
    ndim = len(shape)
    base: Tuple = ()

    if name in ("embed", "patch_proj_in"):
        # vocab over tp ONLY: FSDP-sharding the table's d_model dim triggers
        # a pathological 512-way SPMD partitioning path for tied embeddings
        # (gemma3 multi-pod: stuck >10 min -> 11 s) and adds lookup gathers;
        # the table is small per-shard (<=160 MB / tp16) so replication over
        # dp is the right trade at pod scale.
        base = (tp, None)
    elif name == "unembed":
        base = (fsdp, tp)                       # (D, V)
    elif name in ("w_q", "w_k", "w_v"):
        base = (fsdp, tp)                       # (D, H*hd)
    elif name == "w_o":
        base = (tp, fsdp)                       # (H*hd, D)
    elif name in ("w_gate", "w_up"):
        if ndim == 3:                           # MoE experts (E, D, F)
            base = (tp, fsdp, None) if expert_parallel else (None, fsdp, tp)
        else:
            base = (fsdp, tp)                   # (D, F)
    elif name == "w_down":
        if ndim == 3:                           # (E, F, D)
            base = (tp, None, fsdp) if expert_parallel else (None, tp, fsdp)
        else:
            base = (tp, fsdp)                   # (F, D)
    elif name == "router":
        base = (fsdp, None)
    elif name == "in_proj":
        base = (fsdp, tp)                       # ssm: (D, Din)
    elif name == "out_proj":
        base = (tp, fsdp)                       # ssm: (Din, D)
    elif name in ("conv_w", "conv_b"):
        base = (None,) * (ndim - 1) + (tp,)     # channels over tp
    elif name in ("a_log", "d_skip", "dt_bias"):
        base = (tp,)
    else:                                       # norms, scalars: replicated
        base = (None,) * ndim

    base = tuple(base)[:ndim] + (None,) * max(0, ndim - len(base))
    # stacked-layer leading dim (scan over layers): never sharded
    if ndim > len(base):
        base = (None,) + base
    return P(*base)


def param_shardings(params, ctx: ShardCtx, expert_parallel: bool = False,
                    n_layers_stacked: bool = True):
    """PartitionSpec pytree matching ``params`` (path-name rules)."""

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        shape = node.shape
        stacked = n_layers_stacked and "/layers/" in path + "/"
        core_shape = shape[1:] if stacked and len(shape) > 1 else shape
        spec = _spec_for(path if not stacked else path, core_shape, ctx,
                         expert_parallel)
        parts = tuple(spec)
        if stacked and len(shape) > 1:
            parts = (None,) + parts
        parts = parts[: len(shape)]
        parts = parts + (None,) * (len(shape) - len(parts))
        # divisibility guard: drop axis sharding that does not divide
        fixed = []
        for dim, ax in zip(shape, parts):
            if ax is None:
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= ctx.mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(ctx.mesh, P(*fixed))

    return walk(params, "")
