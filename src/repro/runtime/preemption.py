"""Preemption / failure handling for long-running training jobs.

* ``GracefulShutdown`` — converts SIGTERM/SIGINT into a flag the train loop
  polls each step; on preemption the loop writes a final checkpoint and
  exits cleanly (the scheduler restarts the job, which auto-resumes).
* ``Watchdog`` — a heartbeat thread that detects a stalled step (straggler
  or wedged collective) and invokes a callback (in production: report the
  slow host to the control plane and trigger elastic restart without it;
  here: log + optional exception for tests).
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional

__all__ = ["GracefulShutdown", "Watchdog"]


class GracefulShutdown:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def requested(self) -> bool:
        return self._stop.is_set()

    def request(self):
        """Programmatic preemption (tests)."""
        self._stop.set()


class Watchdog:
    """Fires ``on_stall`` if ``beat()`` is not called within ``timeout_s``."""

    def __init__(self, timeout_s: float,
                 on_stall: Optional[Callable[[float], None]] = None,
                 poll_s: float = 0.1):
        self.timeout_s = timeout_s
        self.on_stall = on_stall or (lambda dt: None)
        self._last = time.monotonic()
        self._stalled = threading.Event()
        self._stop = threading.Event()
        self._poll = poll_s
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    @property
    def stalled(self) -> bool:
        return self._stalled.is_set()

    def _run(self):
        while not self._stop.is_set():
            dt = time.monotonic() - self._last
            if dt > self.timeout_s and not self._stalled.is_set():
                self._stalled.set()
                self.on_stall(dt)
            time.sleep(self._poll)

    def stop(self):
        self._stop.set()
