"""Fault-tolerant checkpointing: sharded, atomic, async, elastic.

Layout of a checkpoint directory::

    <dir>/step_000123/
        manifest.json      # step, config fingerprint, tree structure, shapes
        arrays.npz         # flat {index -> ndarray}, full (unsharded) values
    <dir>/LATEST           # atomic pointer file

Design choices for 1000+ node deployments (documented trade-offs):

  * **Atomicity**: writes go to ``step_X.tmp-<pid>`` then ``os.rename`` —
    a crashed writer never corrupts the pointer; LATEST is rewritten last.
  * **Async**: ``save_async`` snapshots device arrays to host (blocking only
    for the device->host copy) and writes in a daemon thread — training
    continues during serialization (measured overlap in benchmarks).
  * **Elastic**: checkpoints store *logical* (global-shape) arrays; restore
    re-shards onto whatever mesh is active — axis sizes may differ between
    save and load (tested: 8 -> 4 -> 8 CPU devices).
  * On a real fleet the npz payload would be a per-host shard (tensorstore);
    the manifest/pointer protocol is identical.  This container is
    single-host, so full-value npz is the honest equivalent.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "config_fingerprint"]

_TMP_COUNTER = itertools.count()


def config_fingerprint(cfg) -> str:
    if dataclasses.is_dataclass(cfg):
        payload = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    else:
        payload = repr(cfg)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any,
         fingerprint: str = "") -> Path:
    """Synchronous atomic checkpoint write."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / (f"step_{step:08d}.tmp-{os.getpid()}"
                      f"-{next(_TMP_COUNTER)}")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    host = [np.asarray(leaf) for leaf in leaves]
    np.savez(tmp / "arrays.npz", **{str(i): a for i, a in enumerate(host)})
    manifest = {
        "step": step,
        "fingerprint": fingerprint,
        "treedef": str(treedef),
        "n_leaves": len(host),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "time": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    ptr_tmp = ckpt_dir / f".LATEST.tmp-{os.getpid()}-{next(_TMP_COUNTER)}"
    ptr_tmp.write_text(final.name)
    os.rename(ptr_tmp, ckpt_dir / "LATEST")
    return final


class _AsyncSaver:
    """Single background writer; at most one outstanding save (newer wins)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def submit(self, ckpt_dir, step, tree, fingerprint=""):
        # snapshot to host synchronously (cheap vs serialization)
        leaves, treedef = _flatten(tree)
        host = [np.asarray(leaf) for leaf in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save(ckpt_dir, step, snapshot, fingerprint)

        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                self._thread.join()  # backpressure: never queue > 1
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        with self._lock:
            if self._thread is not None:
                self._thread.join()


_SAVER = _AsyncSaver()


def save_async(ckpt_dir, step, tree, fingerprint=""):
    _SAVER.submit(ckpt_dir, step, tree, fingerprint)


def wait_for_saves():
    _SAVER.wait()


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ptr = Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (Path(ckpt_dir) / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str | Path, like: Any, step: Optional[int] = None,
            shardings: Any = None, expect_fingerprint: str = ""):
    """Restore into the structure of ``like``; re-shard via ``shardings``.

    ``shardings`` (optional) is a pytree of NamedSharding matching ``like``
    — this is the elastic path: the stored global arrays are placed onto the
    *current* mesh regardless of the mesh they were saved from.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if expect_fingerprint and manifest["fingerprint"] != expect_fingerprint:
        raise ValueError(
            f"checkpoint fingerprint {manifest['fingerprint']} != expected "
            f"{expect_fingerprint} — refusing to load a mismatched config"
        )
    data = np.load(d / "arrays.npz")
    leaves, treedef = _flatten(like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError("checkpoint structure mismatch")
    out = []
    for i, ref in enumerate(leaves):
        a = data[str(i)]
        if tuple(a.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {a.shape} != {ref.shape}")
        out.append(a)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    else:
        restored = jax.tree_util.tree_map(jax.numpy.asarray, restored)
    return restored, step
