"""§Perf hillclimb driver: re-runs a dry-run cell under named optimization
variants and records before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.perf_iter --cell smollm-360m:train_4k \
        --variant attn_seq_shard

Variants (each is one hypothesis from the §Perf log):
  attn_seq_shard — shard the S^2 attention einsums over query-sequence when
                   n_heads %% tp != 0 (kills replicated compute)
  chunked_ce     — scan the CE loss over sequence chunks (peak-memory cut)
  noremat        — disable activation checkpointing (FLOPs down, memory up)
  all            — attn_seq_shard + chunked_ce
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses      # noqa: E402
import json             # noqa: E402

from repro.configs.registry import get_config  # noqa: E402
from repro.launch import dryrun  # noqa: E402

VARIANTS = {
    "attn_seq_shard": dict(ctx=dict(attn_seq_shard=True), cfg={}, train={}),
    "chunked_ce": dict(ctx={}, cfg={}, train=dict(chunked_ce=512)),
    "noremat": dict(ctx={}, cfg=dict(remat=True), train={}),
    "all": dict(ctx=dict(attn_seq_shard=True), cfg={},
                train=dict(chunked_ce=512)),
}
VARIANTS["noremat"]["cfg"] = dict(remat=False)


def run_variant(arch: str, shape: str, variant: str, force=False):
    v = VARIANTS[variant]
    dryrun.CTX_KW.clear()
    dryrun.CTX_KW.update(v["ctx"])
    dryrun.TRAIN_KW.clear()
    dryrun.TRAIN_KW.update(v["train"])
    cfg = get_config(arch)
    if v["cfg"]:
        cfg = dataclasses.replace(cfg, **v["cfg"])
    rec = dryrun.run_cell(arch, shape, multi_pod=False, force=force,
                          cfg_override=cfg, variant=variant)
    dryrun.CTX_KW.clear()
    dryrun.TRAIN_KW.clear()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", required=True,
                    choices=list(VARIANTS) + ["baseline"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    if args.variant == "baseline":
        rec = dryrun.run_cell(arch, shape, multi_pod=False, force=args.force)
    else:
        rec = run_variant(arch, shape, args.variant, force=args.force)
    out = {k: rec.get(k) for k in ("cell", "status", "compile_s",
                                   "unroll_compile_s", "error")}
    if rec.get("roofline"):
        out["roofline"] = rec["roofline"]
        out["collectives_total_gb"] = rec["collectives"]["total"] / 1e9
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
