"""Roofline-term derivation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the post-SPMD optimized HLO
(``compiled.as_text()``): we sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

TPU v5e hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["HW", "collective_bytes", "roofline_terms", "Roofline"]


class HW:
    PEAK_FLOPS = 197e12        # bf16 per chip
    HBM_BW = 819e9             # bytes/s per chip
    LINK_BW = 50e9             # bytes/s per ICI link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        kind = None
        rhs_head = rhs.lstrip()
        for k in _COLLECTIVES:
            # op name directly after result type(s)
            if re.search(rf"\b{k}(-start|-done)?\(", rhs_head):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs_head:
            continue  # counted at -start
        n = 0
        # result type(s) appear at the start of rhs, before the op name
        head = rhs_head.split(kind)[0]
        for m in _SHAPE_RE.finditer(head):
            n += _shape_bytes(m.group(1), m.group(2))
        out[kind] += n
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    n_chips: int
    tokens_per_step: int = 0
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * HW.PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HW.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.n_chips * HW.LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Optimistic perfectly-overlapped step time: max of the terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        if self.model_flops and self.step_time > 0:
            return self.model_flops / (
                self.n_chips * HW.PEAK_FLOPS * self.step_time)
        return 0.0

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "n_chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_at_roofline": self.mfu,
            "tokens_per_step": self.tokens_per_step,
        }


def roofline_terms(cost: dict, coll: dict, n_chips: int,
                   model_flops: float = 0.0,
                   tokens_per_step: int = 0) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        flops=flops, hbm_bytes=byts, coll_bytes=float(coll.get("total", 0)),
        n_chips=n_chips, model_flops=model_flops,
        tokens_per_step=tokens_per_step,
    )
