"""Aggregate dry-run artifacts into the EXPERIMENTS.md summary tables.

    PYTHONPATH=src python -m repro.launch.summarize
writes artifacts/roofline.md and artifacts/summary.json, and prints the
headline counts.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def load_cells():
    cells = []
    for f in sorted(ART.glob("*.json")):
        try:
            cells.append(json.loads(f.read_text()))
        except Exception:
            pass
    return cells


def main():
    cells = load_cells()
    base = [c for c in cells if len(c["cell"].split("__")) == 3]
    variants = [c for c in cells if len(c["cell"].split("__")) > 3]

    ok = [c for c in base if c["status"] == "ok"]
    skipped = [c for c in base if c["status"] == "skipped"]
    errors = [c for c in base if c["status"] == "error"]

    md = ["# Roofline table (single-pod baseline; multi-pod = compile proof)",
          "",
          "| cell | compile (s) | t_comp (s) | t_mem (s) | t_coll (s) | "
          "bottleneck | useful FLOPs | MFU@roofline | coll GB |",
          "|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(ok, key=lambda c: c["cell"]):
        rl = c.get("roofline") or {}
        if not rl or not rl.get("flops"):
            md.append(f"| {c['cell']} | {c.get('compile_s')} | - | - | - | "
                      f"(scanned-only) | - | - | "
                      f"{c.get('collectives', {}).get('total', 0)/1e9:.2f} |")
            continue
        md.append(
            f"| {c['cell']} | {c.get('compile_s')} | "
            f"{rl['t_compute_s']:.4g} | {rl['t_memory_s']:.4g} | "
            f"{rl['t_collective_s']:.4g} | {rl['bottleneck']} | "
            f"{rl['useful_flops_frac']:.3f} | {rl['mfu_at_roofline']:.2%} | "
            f"{c['collectives']['total']/1e9:.2f} |")
    md.append("")
    md.append("## Skipped by design")
    for c in sorted(skipped, key=lambda c: c["cell"]):
        md.append(f"- {c['cell']}: {c.get('reason', '')[:120]}")
    if errors:
        md.append("")
        md.append("## Errors")
        for c in errors:
            md.append(f"- {c['cell']}: {c.get('error', '')[:200]}")
    if variants:
        md.append("")
        md.append("## §Perf variants")
        md.append("| variant cell | t_comp | t_mem | t_coll | bottleneck | "
                  "useful | coll GB |")
        md.append("|---|---|---|---|---|---|---|")
        for c in sorted(variants, key=lambda c: c["cell"]):
            rl = c.get("roofline") or {}
            if c["status"] != "ok" or not rl:
                md.append(f"| {c['cell']} | {c.get('status')} "
                          f"{c.get('error', '')[:80]} | | | | | |")
                continue
            md.append(
                f"| {c['cell']} | {rl['t_compute_s']:.4g} | "
                f"{rl['t_memory_s']:.4g} | {rl['t_collective_s']:.4g} | "
                f"{rl['bottleneck']} | {rl['useful_flops_frac']:.3f} | "
                f"{c['collectives']['total']/1e9:.2f} |")

    out = ART.parent / "roofline.md"
    out.write_text("\n".join(md) + "\n")
    summary = {
        "ok": len(ok), "skipped": len(skipped), "errors": len(errors),
        "variants": len(variants),
        "by_mesh": {
            m: sum(1 for c in ok if c["mesh"] == m)
            for m in ("single_pod", "multi_pod")
        },
    }
    (ART.parent / "summary.json").write_text(json.dumps(summary, indent=1))
    print(json.dumps(summary, indent=1))
    for c in errors:
        print("ERROR", c["cell"], c.get("error", "")[:160])


if __name__ == "__main__":
    main()
