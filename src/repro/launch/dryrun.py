import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This proves the production mesh lowers + compiles;
# smoke tests and benchmarks run in normal single-device processes.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, cell_is_applicable, input_specs  # noqa: E402
from repro.distributed.sharding import (ShardCtx, param_shardings,  # noqa: E402
                                        use_ctx)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import collective_bytes, roofline_terms  # noqa: E402
from repro.models.transformer import init_lm  # noqa: E402
from repro.models.whisper import init_encdec  # noqa: E402
from repro.serving.decode import decode_step, prefill  # noqa: E402
from repro.training.optimizer import AdamWConfig  # noqa: E402
from repro.training.train_step import init_train_state, make_train_step  # noqa: E402

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# hillclimb knobs set per-variant by perf_iter.py (default = baseline)
CTX_KW: dict = {}
TRAIN_KW: dict = {}


def _ctx_for(mesh, cfg, shape) -> ShardCtx:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    seq_shard = shape.seq_len >= 32_768 and shape.kind != "decode"
    return ShardCtx(mesh=mesh, dp=dp, tp="model", seq_shard=seq_shard,
                    **CTX_KW)


def _batch_shardings(tree, ctx):
    def spec(x):
        nd = len(x.shape)
        parts = [None] * nd
        if x.shape[0] % _axis_size(ctx, ctx.dp_spec) == 0:
            parts[0] = ctx.dp_spec
        return NamedSharding(ctx.mesh, P(*parts))

    return jax.tree_util.tree_map(spec, tree)


def _axis_size(ctx, ax) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= ctx.mesh.shape[a]
        return n
    return ctx.mesh.shape[ax]


def _decode_state_shardings(state_sds, ctx):
    """Caches: batch over dp when divisible; kv-heads over tp when divisible,
    else cache-seq over tp (few-kv-head archs; uneven shards are padded)."""
    tp_size = _axis_size(ctx, ctx.tp)
    dp_size = _axis_size(ctx, ctx.dp_spec)

    def spec(x):
        nd = len(x.shape)
        parts = [None] * nd
        if nd >= 2 and x.shape[1] % dp_size == 0:
            parts[1] = ctx.dp_spec          # (L, B, ...) batch
        if nd == 5:                          # (L, B, W, H, D) kv cache
            if x.shape[3] % tp_size == 0:
                parts[3] = ctx.tp
            else:
                parts[2] = ctx.tp
        elif nd == 4:                        # (L, B, H*, ...) ssm state/conv
            if x.shape[2] % tp_size == 0:
                parts[2] = ctx.tp
            elif x.shape[3] % tp_size == 0:
                parts[3] = ctx.tp
        return NamedSharding(ctx.mesh, P(*parts))

    return jax.tree_util.tree_map(spec, state_sds)


def _init_fn(cfg):
    return init_encdec if cfg.family == "audio" else init_lm


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override=None):
    """Returns (fn, args_sds, in_shardings) for one (arch x shape x mesh)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = _ctx_for(mesh, cfg, shape)
    kwargs_sds, meta = input_specs(cfg, shape)

    key = jax.random.PRNGKey(0)
    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        state_sds = jax.eval_shape(
            lambda: init_train_state(_init_fn(cfg)(cfg, key), opt_cfg))
        pshard = param_shardings(state_sds.params, ctx,
                                 expert_parallel=cfg.expert_parallel)
        state_shard = type(state_sds)(
            params=pshard,
            opt=type(state_sds.opt)(
                step=NamedSharding(mesh, P()),
                mu=pshard, nu=pshard),
            step=NamedSharding(mesh, P()),
        )
        batch_shard = _batch_shardings(kwargs_sds["batch"], ctx)
        step = make_train_step(cfg, opt_cfg, **TRAIN_KW)

        def fn(state, batch):
            with use_ctx(ctx):
                return step(state, batch)

        return (fn, (state_sds, kwargs_sds["batch"]),
                (state_shard, batch_shard), cfg, shape, meta, mesh, ctx)

    params_sds = jax.eval_shape(lambda: _init_fn(cfg)(cfg, key))
    pshard = param_shardings(params_sds, ctx,
                             expert_parallel=cfg.expert_parallel)
    if shape.kind == "prefill":
        extras = {k: v for k, v in kwargs_sds.items() if k != "tokens"}
        ex_shard = _batch_shardings(extras, ctx)
        tok_shard = _batch_shardings(kwargs_sds["tokens"], ctx)

        def fn(params, tokens, **ex):
            with use_ctx(ctx):
                return prefill(params, tokens, cfg, **ex)

        args = (params_sds, kwargs_sds["tokens"])
        shards = (pshard, tok_shard)
        if extras:
            return (fn, args + (extras,), shards + (ex_shard,), cfg, shape,
                    meta, mesh, ctx)
        return fn, args, shards, cfg, shape, meta, mesh, ctx

    # decode
    state_sds = kwargs_sds["state"]
    st_shard = _decode_state_shardings(state_sds, ctx)
    tok_shard = _batch_shardings(kwargs_sds["token"], ctx)

    def fn(params, token, state):
        with use_ctx(ctx):
            return decode_step(params, token, state, cfg)

    return (fn, (params_sds, kwargs_sds["token"], state_sds),
            (pshard, tok_shard, st_shard), cfg, shape, meta, mesh, ctx)


def _extras_to_kwargs(fn, args):
    """prefill extras dict (patches/frames) is passed positionally."""
    if isinstance(args[-1], dict) and "tokens" not in args[-1]:
        *pos, ex = args

        def wrapped(*a):
            return fn(*a[:-1], **a[-1])

        return wrapped, tuple(pos) + (ex,)
    return fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             force: bool = False, cfg_override=None,
             variant: str = "") -> dict:
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        cell_id += f"__{variant}"
    out_path = ART / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(out_path, rec)
        return rec

    t0 = time.time()
    try:
        # ---- compile 1: scanned layers — the production artifact ---------
        # proves (lower + compile + memory fit); XLA costs the scan body
        # once, so FLOPs/bytes come from compile 2.
        fn, args, shards, cfg, shape, meta, mesh, ctx = build_cell(
            arch, shape_name, multi_pod)
        fn, args = _extras_to_kwargs(fn, args)
        with mesh:
            jfn = jax.jit(fn, in_shardings=shards)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            mem_d = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:  # CPU backend may not implement it
            mem_d = {"error": str(e)}
        hlo_scanned = compiled.as_text()
        coll_scanned = collective_bytes(hlo_scanned)
        del compiled, lowered

        # ---- compile 2: trip-count-true cost analysis --------------------
        # (single-pod only: the roofline table is single-pod; the multi-pod
        # pass exists to prove the "pod" axis shards.)
        # Two methods: full unroll twin (exact), or for archs whose unrolled
        # compile is prohibitive on 1 CPU core (MoE dispatch x 28-32 layers,
        # enc-dec), the MARGINAL method: compile unrolled twins at L=2 and
        # L=4 and extrapolate linearly in L — exact for layer-homogeneous
        # stacks since cost(L) = other + L * body.
        n_chips = mesh.devices.size
        base_cfg = cfg_override if cfg_override is not None else \
            get_config(arch)
        marginal = arch in ("deepseek-moe-16b", "mixtral-8x7b",
                            "whisper-medium")
        if not multi_pod and not marginal:
            t1 = time.time()
            ucfg = dataclasses.replace(base_cfg, scan_unroll=True)
            fn2, args2, shards2, *_ = build_cell(arch, shape_name, multi_pod,
                                                 cfg_override=ucfg)
            fn2, args2 = _extras_to_kwargs(fn2, args2)
            with mesh:
                compiled2 = jax.jit(fn2, in_shardings=shards2).lower(
                    *args2).compile()
            t_unroll = time.time() - t1
            cost = compiled2.cost_analysis() or {}
            hlo = compiled2.as_text()
            coll = collective_bytes(hlo)
        elif not multi_pod:
            t1 = time.time()
            costs, colls = [], []
            for k in (2, 4):
                kw = dict(n_layers=k, scan_unroll=True)
                if base_cfg.is_encoder_decoder:
                    kw["n_encoder_layers"] = k
                if base_cfg.attn_every:
                    kw["attn_every"] = max(1, k // 2)
                ucfg = dataclasses.replace(base_cfg, **kw)
                fnk, argsk, shardsk, *_ = build_cell(
                    arch, shape_name, multi_pod, cfg_override=ucfg)
                fnk, argsk = _extras_to_kwargs(fnk, argsk)
                with mesh:
                    ck = jax.jit(fnk, in_shardings=shardsk).lower(
                        *argsk).compile()
                costs.append(ck.cost_analysis() or {})
                colls.append(collective_bytes(ck.as_text()))
                del ck
            t_unroll = time.time() - t1
            L = base_cfg.n_layers
            scale = (L - 2) / 2.0

            def extrap(a, b):
                return a + scale * (b - a)

            cost = {k: extrap(float(costs[0].get(k, 0.0)),
                              float(costs[1].get(k, 0.0)))
                    for k in ("flops", "bytes accessed", "transcendentals")}
            coll = {k: int(extrap(colls[0].get(k, 0), colls[1].get(k, 0)))
                    for k in set(colls[0]) | set(colls[1])}
            hlo = hlo_scanned
        else:
            t_unroll = 0.0
            cost = {}
            hlo = hlo_scanned
            coll = coll_scanned

        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.active_param_count() * meta["tokens_per_step"]
        # cost_analysis flops on the partitioned module are per-device;
        # globalize for the roofline (calibrated in tests/test_roofline.py)
        rl = roofline_terms(
            {"flops": float(cost.get("flops", 0.0)) * n_chips,
             "bytes accessed": float(cost.get("bytes accessed", 0.0)) * n_chips},
            coll, n_chips, model_flops=model_flops,
            tokens_per_step=meta["tokens_per_step"])
        # collective bytes are whole-program (already global): undo chip scale
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            unroll_compile_s=round(t_unroll, 2),
            collectives_scanned=coll_scanned,
            n_chips=n_chips,
            cost_analysis={k: cost[k] for k in sorted(cost)[:40]},
            memory_analysis=mem_d,
            collectives=coll,
            hlo_bytes=len(hlo),
            roofline=rl.as_dict(),
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    _write(out_path, rec)
    return rec


def _write(path: Path, rec: dict):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=str))


def run_vdt_cell(multi_pod: bool, force: bool = False,
                 variant: str = "") -> dict:
    """The paper-representative cell: distributed VDT LP step (1M points)."""
    from repro.configs import paper_vdt
    from repro.core.distributed import lp_step_leaforder

    mesh_name = "multi_pod" if multi_pod else "single_pod"
    cell_id = f"paper-vdt__lp_1m__{mesh_name}"
    if variant:
        cell_id += f"__{variant}"
    out_path = ART / f"{cell_id}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    rec = {"cell": cell_id, "arch": "paper-vdt", "shape": "lp_1m",
           "mesh": mesh_name}
    t0 = time.time()
    try:
        specs, meta = paper_vdt.input_specs()
        mesh = make_production_mesh(multi_pod=multi_pod)
        all_axes = tuple(mesh.axis_names)  # every device is a data shard

        def shard1(x, rows_sharded=True):
            parts = [None] * len(x.shape)
            if rows_sharded and x.shape[0] % mesh.devices.size == 0:
                parts[0] = all_axes
            return NamedSharding(mesh, P(*parts))

        shards = {k: shard1(v) for k, v in specs.items()}
        L = meta["L"]

        import jax.numpy as _jnp
        step_kw = {}
        if "sorted" in variant:
            step_kw["sorted_blocks"] = True
        if "bf16" in variant:
            step_kw["carrier_dtype"] = _jnp.bfloat16

        def fn(y_leaf, y0_leaf, a, b, q):
            return lp_step_leaforder(y_leaf, y0_leaf, a, b, q,
                                     paper_vdt.ALPHA, L, **step_kw)

        with mesh:
            lowered = jax.jit(
                fn, in_shardings=tuple(shards[k] for k in specs)
            ).lower(*specs.values())
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_chips = mesh.devices.size
        # matvec useful work: 2 flops per (block x class) + leaf axpy
        model_flops = (2 * paper_vdt.BLOCKS_PER_POINT * paper_vdt.N_POINTS
                       * paper_vdt.N_CLASSES)
        rl = roofline_terms(
            {"flops": float(cost.get("flops", 0.0)) * n_chips,
             "bytes accessed": float(cost.get("bytes accessed", 0.0))
             * n_chips},
            coll, n_chips, model_flops=model_flops,
            tokens_per_step=meta["tokens_per_step"])
        try:
            mem = compiled.memory_analysis()
            mem_d = {k: int(getattr(mem, k))
                     for k in ("argument_size_in_bytes",
                               "output_size_in_bytes", "temp_size_in_bytes")
                     if hasattr(mem, k)}
        except Exception as e:
            mem_d = {"error": str(e)}
        rec.update(status="ok", lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), n_chips=n_chips,
                   collectives=coll, memory_analysis=mem_d,
                   roofline=rl.as_dict(), hlo_bytes=len(hlo))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    _write(out_path, rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.all or args.arch is None:
        for mp in meshes:
            rec = run_vdt_cell(mp, force=args.force)
            print(f"[{rec['status']:7s}] {rec['cell']}", flush=True)
            results.append(rec)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=args.force)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    rl = rec["roofline"]
                    extra = (f" compile={rec['compile_s']}s"
                             f" bottleneck={rl['bottleneck']}"
                             f" step={rl['step_time_s']:.4f}s"
                             f" mfu={rl['mfu_at_roofline']:.2%}")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{status:7s}] {rec['cell']}{extra}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skipped-by-design, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
