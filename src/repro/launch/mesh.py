"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only launch/dryrun.py (its own process) forces 512 host devices.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axis_names"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_names(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
