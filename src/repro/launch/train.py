"""Training launcher: real loop with checkpoint/restart, preemption
handling, deterministic resumable data, and local-mesh sharding.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --smoke --steps 100 --ckpt-dir /tmp/run1

Restarting the same command resumes from the latest checkpoint (elastic:
the device count may differ between runs).  SIGTERM triggers a final
checkpoint + clean exit (preemption-safe).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.distributed.sharding import ShardCtx, use_ctx
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import init_lm
from repro.models.whisper import init_encdec
from repro.runtime import checkpoint as ckpt
from repro.runtime.preemption import GracefulShutdown, Watchdog
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                          total_steps=args.steps)
    mesh = make_local_mesh()
    fingerprint = ckpt.config_fingerprint(cfg)

    ctx = ShardCtx(mesh=mesh, dp=("data",))
    init_fn = init_encdec if cfg.family == "audio" else init_lm
    params = init_fn(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params, opt_cfg)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(args.ckpt_dir, state,
                                         expect_fingerprint=fingerprint)
        print(f"resumed from step {start_step}")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch)

    raw_step = make_train_step(cfg, opt_cfg,
                               n_microbatches=args.microbatches)

    def stepped(state, batch):
        with use_ctx(ctx):
            return raw_step(state, batch)

    train_step = jax.jit(stepped, donate_argnums=0)

    shutdown = GracefulShutdown()
    watchdog = Watchdog(timeout_s=600.0,
                        on_stall=lambda dt: print(f"WATCHDOG: stalled {dt:.0f}s",
                                                  flush=True)).start()
    losses = []
    t0 = time.time()
    for step_i in range(start_step, args.steps):
        batch = {"tokens": jnp.asarray(pipe.batch(step_i))}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32)
        state, metrics = train_step(state, batch)
        watchdog.beat()
        loss = float(metrics["loss"])
        losses.append(loss)
        if step_i % args.log_every == 0 or step_i == args.steps - 1:
            dt = time.time() - t0
            tps = (step_i - start_step + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step_i:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tps:.0f}", flush=True)
        if args.ckpt_dir and (step_i + 1) % args.ckpt_every == 0:
            ckpt.save_async(args.ckpt_dir, step_i + 1, state, fingerprint)
        if shutdown.requested:
            print("preemption requested: checkpointing and exiting")
            if args.ckpt_dir:
                ckpt.save(args.ckpt_dir, step_i + 1, state, fingerprint)
            return 0
    if args.ckpt_dir:
        ckpt.wait_for_saves()
        ckpt.save(args.ckpt_dir, args.steps, state, fingerprint)
    watchdog.stop()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
