"""Pallas TPU kernels for the perf-critical compute layers.

  pairwise        — tiled pairwise squared distances (kNN / exact-P build)
  fused_lp        — flash transition matvec: exact LP step in O(N*block) mem
  flash_attention — causal GQA attention for the LM substrate

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True off-TPU), ref.py (pure-jnp oracle), and a shape/dtype
sweep test asserting allclose against the oracle.
"""
