"""Batched terminating random walks over a padded CSR neighbor table.

The sampling half of the GRF backend (graph random features,
arXiv:2305.00156 / 2410.10368): every node launches ``n_walkers``
independent walkers, and each walker carries an importance-sampling *load*
that keeps the estimator unbiased however the walk is proposed:

* the proposal draws the next hop **uniformly** over the current node's
  neighbors (one gather + one multiply per step — no per-row alias tables
  or prefix sums), and the load multiplies by the importance weight
  ``deg(u) * P[u, v]`` so that ``E[load_t * f(pos_t)] = (P^t f)(start)``
  exactly;
* with ``p_halt > 0`` walkers terminate geometrically; survivors divide
  their load by ``(1 - p_halt)`` per step, so termination thins the walk
  population without biasing it (dead walkers keep stepping with load 0 —
  the arrays stay rectangular and the scan stays shape-static).

Randomness is **per-walker**: walker ``w`` owns key ``split(key, W)[w]``
and derives its step-``t`` draws via ``fold_in(key_w, t)``.  Two
consequences the tests pin:

* determinism — the same ``(key, shapes)`` reproduces the same walks
  bit-for-bit, on any backend, in any batch layout;
* the prefix property — walks of horizon ``T`` are exactly the first ``T``
  steps of horizon ``T' > T`` walks, so one walk set serves every
  intermediate power ``P^t`` of a label-propagation series at once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["walk_step", "sample_walks"]


def walk_step(nbr, prob, deg, pos, load, alive, wkeys, t, p_halt=0.0):
    """Advance every walker one step; returns ``(pos, load, alive)``.

    ``nbr``/``prob`` are the padded ``(N, max_deg)`` neighbor table and
    transition probabilities, ``deg`` the true ``(N,)`` neighbor counts;
    ``pos``/``load``/``alive`` are the ``(W,)`` walker state and ``wkeys``
    the ``(W, 2)`` per-walker keys.  ``t`` (traced) folds into each
    walker's key so every step draws fresh randomness; ``p_halt`` is a
    static python float.
    """
    u = jax.vmap(
        lambda k: jax.random.uniform(jax.random.fold_in(k, t), (2,)))(wkeys)
    d = deg[pos]                                        # (W,) true degrees
    slot = jnp.minimum((u[:, 0] * d).astype(jnp.int32), d - 1)
    nxt = nbr[pos, slot]
    # uniform proposal over deg(u) neighbors -> importance weight deg * P
    mult = d.astype(jnp.float32) * prob[pos, slot]
    if p_halt > 0.0:
        alive = jnp.logical_and(alive, u[:, 1] >= p_halt)
        mult = mult / (1.0 - p_halt)  # survivor correction: stays unbiased
    load = load * mult * alive.astype(jnp.float32)
    return nxt, load, alive


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "n_walkers", "p_halt"))
def sample_walks(nbr, prob, deg, key, *, n_steps: int, n_walkers: int,
                 p_halt: float = 0.0):
    """Full walk histories: ``(pos, load)``, each ``(N, m, n_steps + 1)``.

    ``pos[i, w, t]`` / ``load[i, w, t]`` are walker ``w``-of-node-``i``'s
    position and load after ``t`` steps (``t = 0`` is the start:
    ``pos = i``, ``load = 1``), so ``mean_w load[:, :, t] * f(pos[:, :, t])``
    estimates ``P^t f`` for EVERY ``t <= n_steps`` from one walk set.
    O(N * m * T) memory — the analysis/test surface; the serving estimator
    (``core.grf.grf_label_propagate``) streams the same :func:`walk_step`
    recurrence without storing histories.
    """
    n = nbr.shape[0]
    w = n * n_walkers
    start = jnp.repeat(jnp.arange(n, dtype=jnp.int32), n_walkers)
    wkeys = jax.random.split(key, w)

    def body(carry, t):
        pos, load, alive = walk_step(nbr, prob, deg, *carry, wkeys, t,
                                     p_halt)
        return (pos, load, alive), (pos, load)

    init = (start, jnp.ones((w,), jnp.float32), jnp.ones((w,), bool))
    # steps are numbered 1..T: step t's randomness is fold_in(key_w, t),
    # identical to the streaming estimator's numbering -> bit-parity and
    # the prefix property both hold across the two drivers
    _, (ps, ls) = jax.lax.scan(body, init,
                               jnp.arange(1, n_steps + 1, dtype=jnp.int32))
    pos = jnp.concatenate([start[None], ps], axis=0)          # (T+1, W)
    load = jnp.concatenate([jnp.ones((1, w), jnp.float32), ls], axis=0)
    pos = jnp.moveaxis(pos, 0, -1).reshape(n, n_walkers, n_steps + 1)
    load = jnp.moveaxis(load, 0, -1).reshape(n, n_walkers, n_steps + 1)
    return pos, load
