"""Pure-jnp oracles for the GRF kernels — scipy-free, dense, O(N^2).

``grf_feature_matvec_ref`` is the take-based twin of the Pallas one-hot
kernel (the parity anchor); ``dense_power_action_ref`` / ``dense_lp_ref``
iterate the dense transition matrix directly — the ground truth the
statistical harness (``tests/test_grf.py``) bounds the walker estimators
against with CLT-derived tolerances.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["grf_feature_matvec_ref", "dense_power_action_ref",
           "dense_lp_ref"]


def grf_feature_matvec_ref(pos, load, y):
    """``(1/m) * sum_w load[s, w] * y[pos[s, w], :]`` via ``jnp.take``."""
    y = jnp.asarray(y, jnp.float32)
    gathered = jnp.take(y, jnp.asarray(pos, jnp.int32), axis=0)  # (S, m, C)
    return (gathered * jnp.asarray(load, jnp.float32)[..., None]).mean(axis=1)


def dense_power_action_ref(p, y, t: int):
    """``P^t @ Y`` by ``t`` explicit dense matvecs (no eigendecomposition)."""
    p = jnp.asarray(p, jnp.float32)
    out = jnp.asarray(y, jnp.float32)
    for _ in range(int(t)):
        out = p @ out
    return out


def dense_lp_ref(p, y0, alpha=0.01, n_iters: int = 500):
    """Eq.-15 label propagation against a dense transition matrix.

    ``alpha`` may be a scalar or per-column ``(C,)`` (broadcast against the
    ``(N, C)`` labels) — the same semantics the GRF estimator serves.
    """
    p = jnp.asarray(p, jnp.float32)
    y0 = jnp.asarray(y0, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    y = y0
    for _ in range(int(n_iters)):
        y = alpha * (p @ y) + (1.0 - alpha) * y0
    return y
