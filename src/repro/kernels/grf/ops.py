"""jit'd public wrappers for the GRF walker/feature kernels.

Mirrors ``kernels/fused_lp/ops.py``: every wrapper falls back to Pallas
interpret mode off-TPU so the same call sites run (slowly but correctly)
on CPU test environments.  ``impl="ref"`` selects the take-based jnp
oracle instead of the Pallas one-hot kernel — same contract, used by the
statistical harness's hot loops and by benchmarks that want kernel-free
timings.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.grf.grf import grf_feature_kernel
from repro.kernels.grf.ref import grf_feature_matvec_ref

__all__ = ["grf_feature_matvec"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_s", "block_n"))
def _feature_impl(pos, load, y, block_s: int, block_n: int):
    return grf_feature_kernel(pos, load, y, block_s=block_s,
                              block_n=block_n, interpret=_interpret())


_feature_ref = jax.jit(grf_feature_matvec_ref)


def grf_feature_matvec(pos, load, y, *, block_s: int = 128,
                       block_n: int = 128, impl=None):
    """Walker-mean feature product ``(S, m) x (N, C) -> (S, C)``.

    ``impl=None`` (default) runs the Pallas one-hot-matmul kernel
    (interpret mode off-TPU); ``impl="ref"`` the jnp oracle.
    """
    if impl == "ref":
        return _feature_ref(pos, load, y)
    if impl is not None:
        raise ValueError(f"impl must be None or 'ref', got {impl!r}")
    return _feature_impl(pos, load, y, block_s, block_n)
