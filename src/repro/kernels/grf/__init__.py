from repro.kernels.grf.grf import grf_feature_kernel
from repro.kernels.grf.ops import grf_feature_matvec
from repro.kernels.grf.ref import (dense_lp_ref, dense_power_action_ref,
                                   grf_feature_matvec_ref)
from repro.kernels.grf.walkers import sample_walks, walk_step

__all__ = ["grf_feature_kernel", "grf_feature_matvec",
           "grf_feature_matvec_ref", "dense_power_action_ref",
           "dense_lp_ref", "sample_walks", "walk_step"]
