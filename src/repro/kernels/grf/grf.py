"""Pallas load-weighted feature-product kernel for the GRF estimator.

Reduces one step's walker population to its Monte-Carlo feature estimate

    out[i, :] = (1 / m) * sum_w load[i, w] * Y[pos[i, w], :]

i.e. the walker mean that estimates one row block of ``P^t @ Y``.  The
gather ``Y[pos]`` is phrased as a **weighted one-hot matmul**: each column
tile ``j`` builds a ``(block_s * m, block_n)`` selector holding ``load``
where ``pos`` falls inside the tile and 0 elsewhere, multiplies it against
the resident ``(block_n, C)`` value tile on the MXU, and accumulates —
no dynamic-gather primitive in the kernel body, which TPU Pallas does not
vectorize.  Grid ``(S / block_s, N / block_n)``, column tiles innermost;
tile ``j == 0`` zeroes the output block and every tile accumulates into it.

Out-of-tile positions contribute exactly 0, so padding rows (``load = 0``)
and padded value rows (never indexed: ``pos < N``) are both inert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["grf_feature_kernel"]


def _kernel(pos_ref, load_ref, y_ref, o_ref, *, block_n: int, inv_m: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pos = pos_ref[...]                                  # (bs, m) int32
    load = load_ref[...]                                # (bs, m) f32
    bs, m = pos.shape
    local = pos.reshape(bs * m, 1) - j * block_n
    # TPU wants >= 2-D iota: broadcasted_iota over the tile's column axis
    cols = jax.lax.broadcasted_iota(jnp.int32, (bs * m, block_n), 1)
    onehot = jnp.where(local == cols, load.reshape(bs * m, 1),
                       jnp.float32(0.0))
    part = jnp.dot(onehot, y_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (bs*m, C)
    o_ref[...] += inv_m * part.reshape(bs, m, -1).sum(axis=1)


def grf_feature_kernel(pos, load, y, *, block_s: int = 128,
                       block_n: int = 128, interpret: bool = False):
    """``(S, m)`` walker positions/loads x ``(N, C)`` values -> ``(S, C)``.

    Pads S up to ``block_s`` (zero load — inert) and N up to ``block_n``
    (padded value rows are never selected); slices the padding back off.
    """
    s, m = pos.shape
    n, c = y.shape
    pos = jnp.asarray(pos, jnp.int32)
    load = jnp.asarray(load, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    bs = min(block_s, _round_up(s, 8))
    bn = min(block_n, _round_up(n, 128))
    sp = _round_up(s, bs)
    np_ = _round_up(n, bn)
    if sp != s:
        pos = jnp.pad(pos, ((0, sp - s), (0, 0)))
        load = jnp.pad(load, ((0, sp - s), (0, 0)))
    if np_ != n:
        y = jnp.pad(y, ((0, np_ - n), (0, 0)))
    grid = (sp // bs, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, block_n=bn, inv_m=1.0 / m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bs, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bs, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, c), jnp.float32),
        interpret=interpret,
    )(pos, load, y)
    return out[:s]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult
