from repro.kernels.fused_lp.ops import (fused_lp_matvec,
                                        fused_lp_matvec_batched,
                                        fused_lp_scan_batched,
                                        fused_lp_scan_batched_resume,
                                        fused_lp_scan_folded,
                                        fused_lp_scan_folded_resume,
                                        fused_lp_step_batched,
                                        fused_lp_step_folded)
from repro.kernels.fused_lp.ref import (dense_transition_ref,
                                        fused_lp_matvec_batched_ref,
                                        fused_lp_matvec_dense_ref,
                                        fused_lp_matvec_ref,
                                        fused_lp_scan_batched_ref,
                                        fused_lp_step_batched_ref)

__all__ = ["fused_lp_matvec", "fused_lp_matvec_batched",
           "fused_lp_step_batched", "fused_lp_step_folded",
           "fused_lp_scan_folded", "fused_lp_scan_batched",
           "fused_lp_scan_folded_resume", "fused_lp_scan_batched_resume",
           "fused_lp_matvec_ref", "fused_lp_matvec_dense_ref",
           "fused_lp_matvec_batched_ref", "fused_lp_step_batched_ref",
           "fused_lp_scan_batched_ref", "dense_transition_ref"]
