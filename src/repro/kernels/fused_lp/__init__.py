from repro.kernels.fused_lp.ops import fused_lp_matvec
from repro.kernels.fused_lp.ref import (fused_lp_matvec_dense_ref,
                                        fused_lp_matvec_ref)

__all__ = ["fused_lp_matvec", "fused_lp_matvec_ref",
           "fused_lp_matvec_dense_ref"]
