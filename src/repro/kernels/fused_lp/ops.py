"""jit'd public wrappers for the fused LP matvec / batched LP-step kernels.

All wrappers fall back to Pallas interpret mode off-TPU so the same call
sites run (slowly but correctly) on CPU test environments.

Batched dispatch
----------------
``fused_lp_step_batched`` / ``fused_lp_matvec_batched`` default to the
**distance-reusing** layout (``reuse=True``): the batch folds into the
channel axis so each pairwise-distance tile and its online-softmax
normalizer is computed once for all ``B`` right-hand sides (see
``batched.py``).  ``reuse=False`` selects the legacy per-batch-recompute
grid ``(B, M, N)`` — kept so the bench gate can measure the reuse win and
parity tests can pin both layouts to the dense reference.

On the reuse path ``alpha`` is a *traced* scalar or per-request ``(B,)``
array (serving different alphas never recompiles); the legacy path bakes a
static float ``alpha`` into the kernel as before.

``fused_lp_scan_batched`` / ``fused_lp_scan_folded`` run the whole
``n_iters`` LP recursion in one jitted ``lax.scan`` with ``Y`` resident on
device in the folded layout — the multi-iteration form the exact serving
backend (``core.label_prop.lp_scan_fused``) dispatches to.
"""
import functools

import jax

from repro.kernels.fused_lp.batched import (
    fused_lp_scan_batched_reuse_kernel,
    fused_lp_scan_folded_kernel,
    fused_lp_step_batched_kernel,
    fused_lp_step_batched_reuse_kernel,
    fused_lp_step_folded_kernel,
)
from repro.kernels.fused_lp.fused_lp import fused_lp_matvec_kernel

__all__ = ["fused_lp_matvec", "fused_lp_matvec_batched",
           "fused_lp_step_batched", "fused_lp_step_folded",
           "fused_lp_scan_folded", "fused_lp_scan_batched"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def fused_lp_matvec(x, y, sigma: float, block_m: int = 256,
                    block_n: int = 256):
    return fused_lp_matvec_kernel(
        x, y, sigma, block_m=block_m, block_n=block_n,
        interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def fused_lp_step_folded(x, y, y0, sigma: float, alpha=1.0,
                         block_m: int = 256, block_n: int = 256):
    """One eq.-15 step in the folded (N, K) layout, distances computed once.

    ``alpha`` is traced: a scalar or a per-column ``(K,)`` array.
    """
    return fused_lp_step_folded_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def _step_batched_reuse(x, y, y0, sigma: float, alpha,
                        block_m: int = 256, block_n: int = 256):
    return fused_lp_step_batched_reuse_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("sigma", "alpha", "block_m", "block_n"))
def _step_batched_perbatch(x, y, y0, sigma: float, alpha: float,
                           block_m: int = 256, block_n: int = 256):
    return fused_lp_step_batched_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret())


def fused_lp_step_batched(x, y, y0, sigma: float, alpha=0.01,
                          block_m: int = 256, block_n: int = 256,
                          reuse: bool = True):
    """One fused eq.-15 LP update for a (B, N, C) stack of label matrices.

    ``reuse=True`` (default) computes each distance tile once for the whole
    batch and accepts a traced scalar or per-request ``(B,)`` ``alpha``;
    ``reuse=False`` is the legacy per-batch-recompute kernel, which requires
    a static float ``alpha``.
    """
    if reuse:
        return _step_batched_reuse(x, y, y0, sigma, alpha,
                                   block_m=block_m, block_n=block_n)
    return _step_batched_perbatch(x, y, y0, sigma, float(alpha),
                                  block_m=block_m, block_n=block_n)


def fused_lp_matvec_batched(x, ys, sigma: float, block_m: int = 256,
                            block_n: int = 256, reuse: bool = True):
    """P @ Y[b] for a (B, N, C) stack; alpha=1 degenerates the LP step."""
    if reuse:
        return _step_batched_reuse(x, ys, ys, sigma, 1.0,
                                   block_m=block_m, block_n=block_n)
    return _step_batched_perbatch(x, ys, ys, sigma, 1.0,
                                  block_m=block_m, block_n=block_n)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "n_iters", "block_m", "block_n"))
def fused_lp_scan_folded(x, y0, sigma: float, alpha, n_iters: int,
                         block_m: int = 256, block_n: int = 256):
    """``n_iters`` fused eq.-15 steps, Y resident on device in folded layout."""
    return fused_lp_scan_folded_kernel(
        x, y0, sigma, alpha, int(n_iters), block_m=block_m, block_n=block_n,
        interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("sigma", "n_iters", "block_m", "block_n"))
def fused_lp_scan_batched(x, y0s, sigma: float, alpha, n_iters: int,
                          block_m: int = 256, block_n: int = 256):
    """Whole batched LP run over a (B, N, C) stack: fold once, scan, unfold.

    ``alpha`` is a traced scalar or per-request ``(B,)`` array.
    """
    return fused_lp_scan_batched_reuse_kernel(
        x, y0s, sigma, alpha, int(n_iters),
        block_m=block_m, block_n=block_n, interpret=_interpret())
