"""jit'd public wrappers for the fused LP matvec / batched LP-step kernels.

All wrappers fall back to Pallas interpret mode off-TPU so the same call
sites run (slowly but correctly) on CPU test environments.
"""
import functools

import jax

from repro.kernels.fused_lp.batched import fused_lp_step_batched_kernel
from repro.kernels.fused_lp.fused_lp import fused_lp_matvec_kernel

__all__ = ["fused_lp_matvec", "fused_lp_matvec_batched",
           "fused_lp_step_batched"]


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def fused_lp_matvec(x, y, sigma: float, block_m: int = 256,
                    block_n: int = 256):
    return fused_lp_matvec_kernel(
        x, y, sigma, block_m=block_m, block_n=block_n,
        interpret=jax.default_backend() != "tpu")


@functools.partial(jax.jit,
                   static_argnames=("sigma", "alpha", "block_m", "block_n"))
def fused_lp_step_batched(x, y, y0, sigma: float, alpha: float = 0.01,
                          block_m: int = 256, block_n: int = 256):
    """One fused eq.-15 LP update for a (B, N, C) stack of label matrices."""
    return fused_lp_step_batched_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=jax.default_backend() != "tpu")


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def fused_lp_matvec_batched(x, ys, sigma: float, block_m: int = 256,
                            block_n: int = 256):
    """P @ Y[b] for a (B, N, C) stack; alpha=1 degenerates the LP step."""
    return fused_lp_step_batched_kernel(
        x, ys, ys, sigma, 1.0, block_m=block_m, block_n=block_n,
        interpret=jax.default_backend() != "tpu")
