"""jit'd public wrappers for the fused LP matvec / batched LP-step kernels.

All wrappers fall back to Pallas interpret mode off-TPU so the same call
sites run (slowly but correctly) on CPU test environments.

Batched dispatch
----------------
``fused_lp_step_batched`` / ``fused_lp_matvec_batched`` default to the
**distance-reusing** layout (``reuse=True``): the batch folds into the
channel axis so each pairwise-divergence tile and its online-softmax
normalizer is computed once for all ``B`` right-hand sides (see
``batched.py``).  ``reuse=False`` selects the legacy per-batch-recompute
grid ``(B, M, N)`` — kept so the bench gate can measure the reuse win and
parity tests can pin both layouts to the dense reference.

On the reuse path ``alpha`` is a *traced* scalar or per-request ``(B,)``
array (serving different alphas never recompiles); the legacy path bakes a
static float ``alpha`` into the kernel as before.

``fused_lp_scan_batched`` / ``fused_lp_scan_folded`` run the whole
``n_iters`` LP recursion in one jitted ``lax.scan`` with ``Y`` resident on
device in the folded layout — the multi-iteration form the exact serving
backend (``core.label_prop.lp_scan_fused``) dispatches to.

Divergences
-----------
Every wrapper takes ``divergence=`` (``None`` | registry name |
``core.divergence.Divergence``) as a *static* jit argument: the kernel's
similarity tile is traced from the divergence's ``tile`` function, so each
divergence compiles its own executable and mixed-divergence traffic can
never share (or cross-contaminate) a compiled kernel.  ``None`` /
``"sqeuclidean"`` keeps the built-in squared-Euclidean tile — bit-identical
to the pre-Bregman kernels.
"""
import functools

import jax

from repro.kernels.fused_lp.batched import (
    fused_lp_scan_batched_resume_kernel,
    fused_lp_scan_batched_reuse_kernel,
    fused_lp_scan_folded_kernel,
    fused_lp_scan_folded_resume_kernel,
    fused_lp_step_batched_kernel,
    fused_lp_step_batched_reuse_kernel,
    fused_lp_step_folded_kernel,
)
from repro.kernels.fused_lp.fused_lp import fused_lp_matvec_kernel

__all__ = ["fused_lp_matvec", "fused_lp_matvec_batched",
           "fused_lp_step_batched", "fused_lp_step_folded",
           "fused_lp_scan_folded", "fused_lp_scan_batched",
           "fused_lp_scan_folded_resume", "fused_lp_scan_batched_resume"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _static_div(divergence):
    """Normalize to the hashable ``Divergence`` BEFORE the jit boundary.

    A ``BoundDivergence`` carries device stats arrays and cannot be hashed
    as a static jit argument; unwrapping here means every public wrapper
    accepts ``None`` | name | ``Divergence`` | ``BoundDivergence`` uniformly
    (matching ``core.label_prop.lp_scan_fused``) instead of failing with an
    opaque unhashable-static-arg error for non-default divergences.
    """
    from repro.core.divergence import resolve_divergence

    return resolve_divergence(divergence)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n",
                                    "divergence"))
def _matvec_impl(x, y, sigma: float, block_m: int, block_n: int, divergence):
    return fused_lp_matvec_kernel(
        x, y, sigma, block_m=block_m, block_n=block_n,
        interpret=_interpret(), divergence=divergence)


def fused_lp_matvec(x, y, sigma: float, block_m: int = 256,
                    block_n: int = 256, divergence=None):
    return _matvec_impl(x, y, sigma, block_m=block_m, block_n=block_n,
                        divergence=_static_div(divergence))


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n",
                                    "divergence"))
def _step_folded_impl(x, y, y0, sigma: float, alpha,
                      block_m: int, block_n: int, divergence):
    return fused_lp_step_folded_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret(), divergence=divergence)


def fused_lp_step_folded(x, y, y0, sigma: float, alpha=1.0,
                         block_m: int = 256, block_n: int = 256,
                         divergence=None):
    """One eq.-15 step in the folded (N, K) layout, divergences computed once.

    ``alpha`` is traced: a scalar or a per-column ``(K,)`` array.
    """
    return _step_folded_impl(x, y, y0, sigma, alpha,
                             block_m=block_m, block_n=block_n,
                             divergence=_static_div(divergence))


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n",
                                    "divergence"))
def _step_batched_reuse(x, y, y0, sigma: float, alpha,
                        block_m: int = 256, block_n: int = 256,
                        divergence=None):
    return fused_lp_step_batched_reuse_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret(), divergence=divergence)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "alpha", "block_m", "block_n",
                                    "divergence"))
def _step_batched_perbatch(x, y, y0, sigma: float, alpha: float,
                           block_m: int = 256, block_n: int = 256,
                           divergence=None):
    return fused_lp_step_batched_kernel(
        x, y, y0, sigma, alpha, block_m=block_m, block_n=block_n,
        interpret=_interpret(), divergence=divergence)


def fused_lp_step_batched(x, y, y0, sigma: float, alpha=0.01,
                          block_m: int = 256, block_n: int = 256,
                          reuse: bool = True, divergence=None):
    """One fused eq.-15 LP update for a (B, N, C) stack of label matrices.

    ``reuse=True`` (default) computes each divergence tile once for the whole
    batch and accepts a traced scalar or per-request ``(B,)`` ``alpha``;
    ``reuse=False`` is the legacy per-batch-recompute kernel, which requires
    a static float ``alpha``.
    """
    divergence = _static_div(divergence)
    if reuse:
        return _step_batched_reuse(x, y, y0, sigma, alpha,
                                   block_m=block_m, block_n=block_n,
                                   divergence=divergence)
    return _step_batched_perbatch(x, y, y0, sigma, float(alpha),
                                  block_m=block_m, block_n=block_n,
                                  divergence=divergence)


def fused_lp_matvec_batched(x, ys, sigma: float, block_m: int = 256,
                            block_n: int = 256, reuse: bool = True,
                            divergence=None):
    """P @ Y[b] for a (B, N, C) stack; alpha=1 degenerates the LP step."""
    divergence = _static_div(divergence)
    if reuse:
        return _step_batched_reuse(x, ys, ys, sigma, 1.0,
                                   block_m=block_m, block_n=block_n,
                                   divergence=divergence)
    return _step_batched_perbatch(x, ys, ys, sigma, 1.0,
                                  block_m=block_m, block_n=block_n,
                                  divergence=divergence)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "n_iters", "block_m", "block_n",
                                    "divergence"))
def _scan_folded_impl(x, y0, sigma: float, alpha, n_iters: int,
                      block_m: int, block_n: int, divergence):
    return fused_lp_scan_folded_kernel(
        x, y0, sigma, alpha, int(n_iters), block_m=block_m, block_n=block_n,
        interpret=_interpret(), divergence=divergence)


def fused_lp_scan_folded(x, y0, sigma: float, alpha, n_iters: int,
                         block_m: int = 256, block_n: int = 256,
                         divergence=None):
    """``n_iters`` fused eq.-15 steps, Y resident on device in folded layout."""
    return _scan_folded_impl(x, y0, sigma, alpha, int(n_iters),
                             block_m=block_m, block_n=block_n,
                             divergence=_static_div(divergence))


@functools.partial(jax.jit,
                   static_argnames=("sigma", "n_iters", "block_m", "block_n",
                                    "divergence"))
def _scan_batched_impl(x, y0s, sigma: float, alpha, n_iters: int,
                       block_m: int, block_n: int, divergence):
    return fused_lp_scan_batched_reuse_kernel(
        x, y0s, sigma, alpha, int(n_iters),
        block_m=block_m, block_n=block_n, interpret=_interpret(),
        divergence=divergence)


def fused_lp_scan_batched(x, y0s, sigma: float, alpha, n_iters: int,
                          block_m: int = 256, block_n: int = 256,
                          divergence=None):
    """Whole batched LP run over a (B, N, C) stack: fold once, scan, unfold.

    ``alpha`` is a traced scalar or per-request ``(B,)`` array.
    """
    return _scan_batched_impl(x, y0s, sigma, alpha, int(n_iters),
                              block_m=block_m, block_n=block_n,
                              divergence=_static_div(divergence))


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n",
                                    "divergence"))
def _scan_folded_resume_impl(x, y, y0, sigma: float, alpha, n_iters,
                             block_m: int, block_n: int, divergence):
    return fused_lp_scan_folded_resume_kernel(
        x, y, y0, sigma, alpha, n_iters, block_m=block_m,
        block_n=block_n, interpret=_interpret(), divergence=divergence)


def fused_lp_scan_folded_resume(x, y, y0, sigma: float, alpha, n_iters: int,
                                block_m: int = 256, block_n: int = 256,
                                divergence=None):
    """``n_iters`` folded eq.-15 steps entered from a mid-walk carry ``y``.

    The segmented-dispatch primitive: bit-identical continuation of the
    monolithic scan (eq. 15 is a pure fixed-point iteration), so a long
    walk can be split into preemptible segments whose carries re-enter here.
    ``n_iters`` is *traced* (dynamic ``fori_loop`` bound): every segment
    length — including odd remainders — reuses one compiled executable per
    shape, and a length-1 tail can never be constant-folded into a
    differently-fused (1-ulp-off) inline body.
    """
    return _scan_folded_resume_impl(x, y, y0, sigma, alpha, int(n_iters),
                                    block_m=block_m, block_n=block_n,
                                    divergence=_static_div(divergence))


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n",
                                    "divergence"))
def _scan_batched_resume_impl(x, ys, y0s, sigma: float, alpha, n_iters,
                              block_m: int, block_n: int, divergence):
    return fused_lp_scan_batched_resume_kernel(
        x, ys, y0s, sigma, alpha, n_iters,
        block_m=block_m, block_n=block_n, interpret=_interpret(),
        divergence=divergence)


def fused_lp_scan_batched_resume(x, ys, y0s, sigma: float, alpha,
                                 n_iters: int, block_m: int = 256,
                                 block_n: int = 256, divergence=None):
    """Batched LP segment over a (B, N, C) carry stack (see folded resume)."""
    return _scan_batched_resume_impl(x, ys, y0s, sigma, alpha, int(n_iters),
                                     block_m=block_m, block_n=block_n,
                                     divergence=_static_div(divergence))
