"""jit'd public wrapper for the fused LP matvec kernel."""
import functools

import jax

from repro.kernels.fused_lp.fused_lp import fused_lp_matvec_kernel

__all__ = ["fused_lp_matvec"]


@functools.partial(jax.jit,
                   static_argnames=("sigma", "block_m", "block_n"))
def fused_lp_matvec(x, y, sigma: float, block_m: int = 256,
                    block_n: int = 256):
    return fused_lp_matvec_kernel(
        x, y, sigma, block_m=block_m, block_n=block_n,
        interpret=jax.default_backend() != "tpu")
