"""Fused "flash" transition matvec Pallas kernel (TPU).

Computes one exact Label-Propagation matvec

    out = row_softmax(-||x_i - x_j||^2 / (2 sigma^2), zero diagonal) @ Y

in a single pass with online max/normalizer (flash-attention style), never
materializing the (N, N) transition matrix P.  This is the beyond-paper TPU
contribution: it turns the paper's O(N^2)-memory "exact" baseline into an
O(N * block) VMEM-resident streaming computation, so the exact model runs at
sizes where P itself could never be stored.

Grid: (M/bm rows, N/bn cols), cols innermost.  VMEM scratch carries the
running max m, normalizer s, and the weighted accumulator acc across column
tiles; the last column tile writes acc / s.

The distance cross-term x @ x_colsᵀ is an MXU matmul; bm/bn are 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_lp_matvec_kernel", "stream_tile_update", "NEG_BIG",
           "tile_config"]

NEG_BIG = -1e30


def tile_config(divergence):
    """``(tile_fn, pad_value, transform)`` for a divergence spec.

    ``tile_fn=None`` selects the inline squared-Euclidean tile in
    :func:`stream_tile_update` — chosen for the default Gaussian (keeping it
    bit-identical to the pre-Bregman kernels) AND for divergences that are
    squared Euclidean after a point pre-map (e.g. Mahalanobis), whose
    ``transform`` the caller applies to the point array *outside* the Pallas
    body — tile functions must not close over array constants, which Pallas
    kernels reject.  Other divergences (KL, Itakura-Saito) supply their
    traced tile function plus the in-domain value points are padded with.
    """
    from repro.core.divergence import resolve_divergence

    div = resolve_divergence(divergence)
    if div.name == "sqeuclidean":
        return None, 0.0, None  # identity transform: skip the extra op
    if div.euclidean_after_transform:
        return None, div.pad_value, div.transform_points
    return div.tile, div.pad_value, div.transform_points


def stream_tile_update(rows_ref, cols_ref, y_tile, m_ref, s_ref, acc_ref,
                       i, j, *, inv_two_sigma_sq: float, n_valid: int,
                       block_m: int, block_n: int, tile_fn=None,
                       row_base=0):
    """One column-tile step of the online-softmax streaming recurrence.

    Shared body of the single-RHS and batched fused-LP kernels: computes
    the tile's masked logits and folds them into the running max m,
    normalizer s and accumulator acc (acc += p @ y_tile).  ``y_tile`` is
    the already-indexed (block_n, C) value tile.  Callers own scratch init
    (at j == 0) and the finishing epilogue (at the last j).

    ``tile_fn`` generalizes the similarity: given the f32 ``(bm, d)`` row
    and ``(bn, d)`` column point tiles it returns the ``(bm, bn)``
    divergence tile (see ``core.divergence.Divergence.tile``).  ``None``
    keeps the built-in squared-Euclidean tile — the default Gaussian path,
    byte-for-byte the pre-Bregman kernel.

    ``row_base`` shifts the *global* row identity of this grid's row
    blocks: the self-transition mask compares ``row_base + i*block_m +
    local`` against column ids.  A caller whose row operand is a slice of
    the full point set (the sharded engine hands each device its own row
    stripe, so every device's ``i`` restarts at 0) passes the stripe's
    global offset; the default 0 is the classic whole-matrix grid.
    """
    x = rows_ref[...].astype(jnp.float32)          # (bm, d)
    xc = cols_ref[...].astype(jnp.float32)         # (bn, d)
    if tile_fn is None:
        xx = jnp.sum(x * x, axis=-1)
        cc = jnp.sum(xc * xc, axis=-1)
        d2 = xx[:, None] + cc[None, :] - 2.0 * jnp.dot(
            x, xc.T, preferred_element_type=jnp.float32)
    else:
        d2 = tile_fn(x, xc)
    logits = -jnp.maximum(d2, 0.0) * inv_two_sigma_sq

    row_ids = row_base + i * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (block_m, block_n), 0)
    col_ids = j * block_n + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_m, block_n), 1)
    invalid = (row_ids == col_ids) | (col_ids >= n_valid)
    logits = jnp.where(invalid, NEG_BIG, logits)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    s_ref[...] = s_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, y_tile.astype(jnp.float32),
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new


def _kernel(rows_ref, cols_ref, y_ref, o_ref, m_ref, s_ref, acc_ref,
            *, inv_two_sigma_sq: float, n_valid: int, block_m: int,
            block_n: int, tile_fn=None):
    i = pl.program_id(0)
    j = pl.program_id(1)
    ncols = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    stream_tile_update(rows_ref, cols_ref, y_ref[...], m_ref, s_ref, acc_ref,
                       i, j, inv_two_sigma_sq=inv_two_sigma_sq,
                       n_valid=n_valid, block_m=block_m, block_n=block_n,
                       tile_fn=tile_fn)

    @pl.when(j == ncols - 1)
    def _finish():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(s_ref[...], 1e-38)[:, None]).astype(
                          o_ref.dtype)


def fused_lp_matvec_kernel(
    x: jax.Array,          # (N, d)
    y: jax.Array,          # (N, C)
    sigma: float,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """P @ Y without materializing P.  O(N^2 d) FLOPs, O(N*block) memory.

    ``divergence`` swaps the tile similarity from ``||a-b||^2`` to any
    registered Bregman divergence; point padding uses the divergence's
    in-domain pad value (masked out of every accumulation by the column
    mask) so KL/IS tiles stay finite on the padded rows/cols.
    """
    tile_fn, pad, transform = tile_config(divergence)
    if transform is not None:
        x = transform(x)
    n, d = x.shape
    c = y.shape[1]
    mp = -(-n // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    xp_rows = jnp.pad(x, ((0, mp - n), (0, 0)), constant_values=pad)
    xp_cols = jnp.pad(x, ((0, np_ - n), (0, 0)), constant_values=pad)
    yp = jnp.pad(y, ((0, np_ - n), (0, 0)))

    kern = functools.partial(
        _kernel,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        n_valid=n, block_m=block_m, block_n=block_n, tile_fn=tile_fn,
    )
    out = pl.pallas_call(
        kern,
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, c), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, c), y.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m, c), jnp.float32),
        ],
        interpret=interpret,
    )(xp_rows, xp_cols, yp)
    return out[:n]
