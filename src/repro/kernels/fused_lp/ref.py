"""Pure-jnp oracles for the fused LP kernels: re-exports the blocked streaming
reference from core.baselines plus direct dense forms (single and batched).

Every dense form takes ``divergence=`` mirroring the kernels: ``None`` (or
``"sqeuclidean"``) is the paper's Gaussian eq. 3, any other registry name
swaps the pairwise similarity for that Bregman divergence — the oracle the
divergence parity grid in ``tests/test_kernels.py`` pins both kernel layouts
against.
"""
import jax
import jax.numpy as jnp

from repro.core.baselines import exact_transition_matrix, streaming_exact_matvec
from repro.core.divergence import resolve_divergence

__all__ = ["fused_lp_matvec_ref", "fused_lp_matvec_dense_ref",
           "fused_lp_matvec_batched_ref", "fused_lp_step_batched_ref",
           "fused_lp_scan_batched_ref", "dense_transition_ref"]


def dense_transition_ref(x, sigma, divergence=None):
    """Dense row-stochastic transition matrix for any registered divergence.

    Row softmax of ``-d(x_i, x_j) / (2 sigma^2)`` with a zero diagonal —
    eq. 3 generalized from the Gaussian kernel to Bregman divergences.
    O(N^2) memory: oracle for tests/benchmarks only.
    """
    div = resolve_divergence(divergence)
    if div.name == "sqeuclidean":
        # delegate to the pre-existing Gaussian oracle (identical formula)
        return exact_transition_matrix(x, jnp.asarray(sigma, jnp.float32))
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    sigma = jnp.asarray(sigma, jnp.float32)
    logits = -div.pairwise(x, x) / (2.0 * sigma * sigma)
    logits = jnp.where(jnp.eye(n, dtype=bool), -jnp.inf, logits)
    return jax.nn.softmax(logits, axis=-1)


def fused_lp_matvec_ref(x, y, sigma):
    return streaming_exact_matvec(x, y, jnp.asarray(sigma, jnp.float32))


def fused_lp_matvec_dense_ref(x, y, sigma, divergence=None):
    p = dense_transition_ref(x, sigma, divergence=divergence)
    return p @ y


def fused_lp_matvec_batched_ref(x, ys, sigma, divergence=None):
    """Dense P applied to every RHS of a (B, N, C) stack."""
    p = dense_transition_ref(x, sigma, divergence=divergence)
    return jnp.einsum("ij,bjc->bic", p, ys)


def fused_lp_step_batched_ref(x, ys, y0s, sigma, alpha, divergence=None):
    """alpha * P @ Y[b] + (1 - alpha) * Y0[b] via the dense P (eq. 15)."""
    return (alpha * fused_lp_matvec_batched_ref(x, ys, sigma,
                                                divergence=divergence)
            + (1.0 - alpha) * y0s)


def fused_lp_scan_batched_ref(x, y0s, sigma, alpha, n_iters, divergence=None):
    """``n_iters`` dense eq.-15 iterations over a (B, N, C) stack.

    ``alpha`` may be a scalar or a per-request ``(B,)`` array (broadcast over
    rows and channels) — the oracle for the multi-iteration reuse kernel.
    """
    p = dense_transition_ref(x, sigma, divergence=divergence)
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1:
        alpha = alpha[:, None, None]
    y = y0s
    for _ in range(int(n_iters)):
        y = alpha * jnp.einsum("ij,bjc->bic", p, y) + (1.0 - alpha) * y0s
    return y
