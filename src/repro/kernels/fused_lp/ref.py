"""Pure-jnp oracle for the fused LP matvec: re-exports the blocked streaming
reference from core.baselines plus a direct dense form."""
import jax
import jax.numpy as jnp

from repro.core.baselines import exact_transition_matrix, streaming_exact_matvec

__all__ = ["fused_lp_matvec_ref", "fused_lp_matvec_dense_ref"]


def fused_lp_matvec_ref(x, y, sigma):
    return streaming_exact_matvec(x, y, jnp.asarray(sigma, jnp.float32))


def fused_lp_matvec_dense_ref(x, y, sigma):
    p = exact_transition_matrix(x, jnp.asarray(sigma, jnp.float32))
    return p @ y
