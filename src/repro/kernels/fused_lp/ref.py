"""Pure-jnp oracles for the fused LP kernels: re-exports the blocked streaming
reference from core.baselines plus direct dense forms (single and batched)."""
import jax.numpy as jnp

from repro.core.baselines import exact_transition_matrix, streaming_exact_matvec

__all__ = ["fused_lp_matvec_ref", "fused_lp_matvec_dense_ref",
           "fused_lp_matvec_batched_ref", "fused_lp_step_batched_ref",
           "fused_lp_scan_batched_ref"]


def fused_lp_matvec_ref(x, y, sigma):
    return streaming_exact_matvec(x, y, jnp.asarray(sigma, jnp.float32))


def fused_lp_matvec_dense_ref(x, y, sigma):
    p = exact_transition_matrix(x, jnp.asarray(sigma, jnp.float32))
    return p @ y


def fused_lp_matvec_batched_ref(x, ys, sigma):
    """Dense P applied to every RHS of a (B, N, C) stack."""
    p = exact_transition_matrix(x, jnp.asarray(sigma, jnp.float32))
    return jnp.einsum("ij,bjc->bic", p, ys)


def fused_lp_step_batched_ref(x, ys, y0s, sigma, alpha):
    """alpha * P @ Y[b] + (1 - alpha) * Y0[b] via the dense P (eq. 15)."""
    return alpha * fused_lp_matvec_batched_ref(x, ys, sigma) + (1.0 - alpha) * y0s


def fused_lp_scan_batched_ref(x, y0s, sigma, alpha, n_iters):
    """``n_iters`` dense eq.-15 iterations over a (B, N, C) stack.

    ``alpha`` may be a scalar or a per-request ``(B,)`` array (broadcast over
    rows and channels) — the oracle for the multi-iteration reuse kernel.
    """
    p = exact_transition_matrix(x, jnp.asarray(sigma, jnp.float32))
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1:
        alpha = alpha[:, None, None]
    y = y0s
    for _ in range(int(n_iters)):
        y = alpha * jnp.einsum("ij,bjc->bic", p, y) + (1.0 - alpha) * y0s
    return y
