"""Batched fused Label-Propagation step Pallas kernels (TPU).

One device dispatch computes, for a stack of ``batch`` independent label
matrices over the SAME point set,

    out[b] = alpha * row_softmax(-||x_i - x_j||^2 / (2 sigma^2), zero diag) @ Y[b]
             + (1 - alpha) * Y0[b]

i.e. a full eq.-15 LP update fused with the exact streaming transition
matvec, never materializing the (N, N) matrix P.  This is the multi-user
serving shape: one fitted model, many concurrent propagation problems.

Two batched layouts implement it:

* **per-batch recompute** (``fused_lp_step_batched_kernel``): grid
  ``(B, M/bm, N/bn)`` — every batch element re-derives the same ``(bm, bn)``
  distance tile and its online-softmax normalizer, so the distance/softmax
  work (the dominant term for small label widths) is paid ``B`` times.
  Kept as the A/B baseline the bench gate measures the reuse win against.

* **distance-reusing** (``fused_lp_step_folded_kernel``): the batch is
  folded into the channel axis, ``(B, N, C) -> (N, B*C)`` (the canonical
  :func:`~repro.core.matvec.fold_batch` layout), and the grid drops to
  ``(M/bm, N/bn)``.  Each distance tile and its normalizer is computed
  ONCE and applied to all ``B`` right-hand sides as a single
  ``(bm, bn) @ (bn, B*C)`` MXU matmul — the paper's "one approximated
  transition matrix amortizes across many random walks" claim realized at
  the kernel level.  FLOPs fall from ``B * N^2 * (d + C)`` to
  ``N^2 * (d + B*C)``, ~``B``-fold for ``C << d``.  Alpha rides as a
  *traced* ``(B*C,)`` per-column row (LP is column-independent), so
  heterogeneous per-request alphas share the dispatch and never grow the
  compile cache.

``fused_lp_scan_folded_kernel`` is the multi-iteration form: it pads once,
keeps ``Y`` resident on device in the folded padded layout across all LP
steps under one ``lax.scan`` (no per-step fold/unfold, no host sync), and
slices back at the end — the serving engine's exact-backend hot loop.

VMEM budget: the reuse kernel's accumulator is ``(bm, B*C)`` f32, so the
folded width ``B*C`` should stay a few thousand columns at ``bm = 256``
(e.g. ``B=32, C=128`` -> 4 MB of a ~16 MB/core VMEM).  The serving layer's
width buckets and ``max_batch`` bound this by construction.

Grid iteration order: cols innermost; VMEM scratch carries the running max
m, normalizer s and weighted accumulator acc across column tiles; the last
column tile applies the fused axpy epilogue ``alpha * acc / s +
(1 - alpha) * y0`` and writes out.  Scratch is re-initialized at every row
tile since the column axis is the fastest-varying grid dimension.

``alpha=1.0`` degenerates to a plain batched matvec (the ``(1-alpha) * Y0``
term vanishes), which is how ``ops.fused_lp_matvec_batched`` calls it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.matvec import fold_batch, unfold_batch
from repro.kernels.fused_lp.fused_lp import NEG_BIG, stream_tile_update, tile_config

__all__ = [
    "fused_lp_step_batched_kernel",
    "fused_lp_step_folded_kernel",
    "fused_lp_step_batched_reuse_kernel",
    "fused_lp_scan_folded_kernel",
    "fused_lp_scan_folded_resume_kernel",
    "fused_lp_scan_batched_reuse_kernel",
    "fused_lp_scan_batched_resume_kernel",
]


# --------------------------------------------------- per-batch recompute path
def _kernel(rows_ref, cols_ref, y_ref, y0_ref, o_ref, m_ref, s_ref, acc_ref,
            *, inv_two_sigma_sq: float, alpha: float, n_valid: int,
            block_m: int, block_n: int, tile_fn=None):
    i = pl.program_id(1)
    j = pl.program_id(2)
    ncols = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    stream_tile_update(rows_ref, cols_ref, y_ref[0], m_ref, s_ref, acc_ref,
                       i, j, inv_two_sigma_sq=inv_two_sigma_sq,
                       n_valid=n_valid, block_m=block_m, block_n=block_n,
                       tile_fn=tile_fn)

    @pl.when(j == ncols - 1)
    def _finish():
        py = acc_ref[...] / jnp.maximum(s_ref[...], 1e-38)[:, None]
        out = alpha * py + (1.0 - alpha) * y0_ref[0].astype(jnp.float32)
        o_ref[...] = out[None].astype(o_ref.dtype)


def fused_lp_step_batched_kernel(
    x: jax.Array,          # (N, d)   shared points
    y: jax.Array,          # (B, N, C) stacked current label matrices
    y0: jax.Array,         # (B, N, C) stacked seed label matrices
    sigma: float,
    alpha: float = 1.0,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """Per-batch-recompute baseline: grid (B, M, N), divergences derived B times.

    Prefer :func:`fused_lp_step_batched_reuse_kernel`; this survives as the
    A/B reference the bench gate holds the reuse kernel's win against.
    """
    tile_fn, pad, transform = tile_config(divergence)
    if transform is not None:
        x = transform(x)
    n, d = x.shape
    batch, _, c = y.shape
    mp = -(-n // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    xp_rows = jnp.pad(x, ((0, mp - n), (0, 0)), constant_values=pad)
    xp_cols = jnp.pad(x, ((0, np_ - n), (0, 0)), constant_values=pad)
    yp = jnp.pad(y, ((0, 0), (0, np_ - n), (0, 0)))
    y0p = jnp.pad(y0, ((0, 0), (0, mp - n), (0, 0)))

    kern = functools.partial(
        _kernel,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        alpha=float(alpha),
        n_valid=n, block_m=block_m, block_n=block_n, tile_fn=tile_fn,
    )
    out = pl.pallas_call(
        kern,
        grid=(batch, mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda b, i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda b, i, j: (j, 0)),
            pl.BlockSpec((1, block_n, c), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, c), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, c), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, mp, c), y.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m, c), jnp.float32),
        ],
        interpret=interpret,
    )(xp_rows, xp_cols, yp, y0p)
    return out[:, :n]


# ----------------------------------------------------- distance-reusing path
def _folded_body(rows_ref, cols_ref, y_ref, y0_ref, alpha_ref, o_ref,
                 m_ref, s_ref, acc_ref, *, inv_two_sigma_sq: float,
                 n_valid: int, block_m: int, block_n: int, tile_fn=None,
                 row_base=0):
    i = pl.program_id(0)
    j = pl.program_id(1)
    ncols = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # one divergence tile + normalizer update for ALL folded columns at once
    stream_tile_update(rows_ref, cols_ref, y_ref[...], m_ref, s_ref, acc_ref,
                       i, j, inv_two_sigma_sq=inv_two_sigma_sq,
                       n_valid=n_valid, block_m=block_m, block_n=block_n,
                       tile_fn=tile_fn, row_base=row_base)

    @pl.when(j == ncols - 1)
    def _finish():
        py = acc_ref[...] / jnp.maximum(s_ref[...], 1e-38)[:, None]
        al = alpha_ref[0].astype(jnp.float32)[None, :]   # (1, K) per-column
        out = al * py + (1.0 - al) * y0_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _folded_kernel(rows_ref, cols_ref, y_ref, y0_ref, alpha_ref, o_ref,
                   m_ref, s_ref, acc_ref, **kw):
    _folded_body(rows_ref, cols_ref, y_ref, y0_ref, alpha_ref, o_ref,
                 m_ref, s_ref, acc_ref, **kw)


def _folded_kernel_rb(rows_ref, cols_ref, y_ref, y0_ref, alpha_ref, rb_ref,
                      o_ref, m_ref, s_ref, acc_ref, **kw):
    # row_base rides as a (1, 1) int32 operand so it may be traced (the
    # sharded engine derives it from lax.axis_index inside shard_map)
    _folded_body(rows_ref, cols_ref, y_ref, y0_ref, alpha_ref, o_ref,
                 m_ref, s_ref, acc_ref, row_base=rb_ref[0, 0], **kw)


def _folded_call(xp_rows, xp_cols, yp, y0p, alpha_row, *,
                 inv_two_sigma_sq: float, n_valid: int,
                 block_m: int, block_n: int, interpret: bool,
                 tile_fn=None, row_base=None) -> jax.Array:
    """pallas_call on already-padded folded operands; returns padded rows.

    ``row_base`` (optional, traced or concrete int32) is the global row id
    of ``xp_rows``'s first row when the row operand is a stripe of the full
    point set; ``None`` keeps the classic whole-matrix program untouched.
    """
    mp, d = xp_rows.shape
    np_ = xp_cols.shape[0]
    k = yp.shape[1]
    kw = dict(inv_two_sigma_sq=inv_two_sigma_sq, n_valid=n_valid,
              block_m=block_m, block_n=block_n, tile_fn=tile_fn)
    in_specs = [
        pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        pl.BlockSpec((1, k), lambda i, j: (0, 0)),
    ]
    operands = [xp_rows, xp_cols, yp, y0p, alpha_row]
    if row_base is None:
        kern = functools.partial(_folded_kernel, **kw)
    else:
        kern = functools.partial(_folded_kernel_rb, **kw)
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (0, 0)))
        operands.append(jnp.asarray(row_base, jnp.int32).reshape(1, 1))
    return pl.pallas_call(
        kern,
        grid=(mp // block_m, np_ // block_n),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, k), yp.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m, k), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


def _alpha_row(alpha, k: int) -> jax.Array:
    """Broadcast scalar / per-column alpha to the (1, K) kernel operand."""
    return jnp.broadcast_to(
        jnp.asarray(alpha, jnp.float32).reshape(-1), (k,))[None]


def fused_lp_step_folded_kernel(
    x: jax.Array,          # (N, d)   shared points
    y: jax.Array,          # (N, K)   folded current labels (K = B*C)
    y0: jax.Array,         # (N, K)   folded seed labels
    sigma: float,
    alpha=1.0,             # traced scalar or (K,) per-column
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """One eq.-15 step in the folded layout; each divergence tile computed once."""
    tile_fn, pad, transform = tile_config(divergence)
    if transform is not None:
        x = transform(x)
    n, _ = x.shape
    k = y.shape[1]
    mp = -(-n // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    out = _folded_call(
        jnp.pad(x, ((0, mp - n), (0, 0)), constant_values=pad),
        jnp.pad(x, ((0, np_ - n), (0, 0)), constant_values=pad),
        jnp.pad(y, ((0, np_ - n), (0, 0))),
        jnp.pad(y0, ((0, mp - n), (0, 0))),
        _alpha_row(alpha, k),
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        n_valid=n, block_m=block_m, block_n=block_n, interpret=interpret,
        tile_fn=tile_fn,
    )
    return out[:n]


def fused_lp_step_batched_reuse_kernel(
    x: jax.Array,          # (N, d)   shared points
    y: jax.Array,          # (B, N, C) stacked current label matrices
    y0: jax.Array,         # (B, N, C) stacked seed label matrices
    sigma: float,
    alpha=1.0,             # traced scalar or (B,) per-request
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """Distance-reusing batched eq.-15 step: fold, one grid pass, unfold."""
    batch, _, c = y.shape
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1:
        # folded column b*C + ch belongs to request b (see fold_batch)
        alpha = jnp.repeat(alpha, c)
    out = fused_lp_step_folded_kernel(
        x, fold_batch(y), fold_batch(y0), sigma, alpha,
        block_m=block_m, block_n=block_n, interpret=interpret,
        divergence=divergence,
    )
    return unfold_batch(out, batch, c)


# ------------------------------------------------------ multi-iteration scan
def fused_lp_scan_folded_resume_kernel(
    x: jax.Array,          # (N, d)
    y: jax.Array,          # (N, K) folded carry: the walk state entering
    y0: jax.Array,         # (N, K) folded seed labels (eq.-15 restart term)
    sigma: float,
    alpha,                 # traced scalar or (K,)
    n_iters,               # TRACED segment length (or concrete int)
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """``n_iters`` fused eq.-15 steps entered from a mid-walk carry ``y``.

    The segmented-dispatch primitive: eq. 15 is a pure fixed-point
    iteration, so running ``n_iters`` steps from the carry of an earlier
    scan continues the monolithic walk *bit-identically* — the per-step
    body is the same program, only the init differs.  ``n_iters`` is a
    *dynamic* ``fori_loop`` bound, deliberately: a static length-1 tail
    segment would let XLA inline the single trip and fuse its epilogue
    differently (observed 1-ulp drift), and every distinct static segment
    length would compile its own executable.  A dynamic bound keeps one
    while-loop executable per shape whose body is the very program the
    monolithic ``lax.scan`` runs, whatever the segment split.

    Rows past ``n`` hold epilogue garbage mid-scan, but the column mask
    (``col >= n_valid``) keeps padding out of every accumulation, so a
    carry re-padded with zeros between segments changes nothing in the
    valid region; the final slice drops pad rows.
    """
    tile_fn, pad, transform = tile_config(divergence)
    if transform is not None:
        x = transform(x)
    n, _ = x.shape
    k = y0.shape[1]
    tile = math.lcm(block_m, block_n)
    sp = -(-n // tile) * tile
    xp = jnp.pad(x, ((0, sp - n), (0, 0)), constant_values=pad)
    yp = jnp.pad(y, ((0, sp - n), (0, 0)))
    y0p = jnp.pad(y0, ((0, sp - n), (0, 0)))
    al = _alpha_row(alpha, k)
    inv = float(1.0 / (2.0 * sigma * sigma))

    def body(_, yc):
        return _folded_call(xp, xp, yc, y0p, al, inv_two_sigma_sq=inv,
                            n_valid=n, block_m=block_m, block_n=block_n,
                            interpret=interpret, tile_fn=tile_fn)

    yc = jax.lax.fori_loop(0, n_iters, body, yp)
    return yc[:n]


def fused_lp_scan_folded_kernel(
    x: jax.Array,          # (N, d)
    y0: jax.Array,         # (N, K) folded seed labels
    sigma: float,
    alpha,                 # traced scalar or (K,)
    n_iters: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """``n_iters`` fused eq.-15 steps with Y resident across iterations.

    Pads once to a common row/col tile multiple so the step's padded output
    feeds straight back as the next step's padded input — the ``lax.scan``
    carries Y in the folded on-device layout, never re-padding, re-folding,
    or touching the host between steps.  Rows past ``n`` hold epilogue
    garbage mid-scan, but the column mask (``col >= n_valid``) keeps them
    out of every accumulation; the final slice drops them.
    """
    tile_fn, pad, transform = tile_config(divergence)
    if transform is not None:
        x = transform(x)
    n, _ = x.shape
    k = y0.shape[1]
    tile = math.lcm(block_m, block_n)
    sp = -(-n // tile) * tile
    xp = jnp.pad(x, ((0, sp - n), (0, 0)), constant_values=pad)
    y0p = jnp.pad(y0, ((0, sp - n), (0, 0)))
    al = _alpha_row(alpha, k)
    inv = float(1.0 / (2.0 * sigma * sigma))

    def step(y, _):
        y = _folded_call(xp, xp, y, y0p, al, inv_two_sigma_sq=inv,
                         n_valid=n, block_m=block_m, block_n=block_n,
                         interpret=interpret, tile_fn=tile_fn)
        return y, None

    y, _ = jax.lax.scan(step, y0p, None, length=n_iters)
    return y[:n]


def fused_lp_scan_batched_reuse_kernel(
    x: jax.Array,          # (N, d)
    y0: jax.Array,         # (B, N, C) stacked seed labels
    sigma: float,
    alpha,                 # traced scalar or (B,)
    n_iters: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """Whole batched LP run: fold once, scan the reuse step, unfold once."""
    batch, _, c = y0.shape
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1:
        alpha = jnp.repeat(alpha, c)
    out = fused_lp_scan_folded_kernel(
        x, fold_batch(y0), sigma, alpha, n_iters,
        block_m=block_m, block_n=block_n, interpret=interpret,
        divergence=divergence,
    )
    return unfold_batch(out, batch, c)


def fused_lp_scan_batched_resume_kernel(
    x: jax.Array,          # (N, d)
    y: jax.Array,          # (B, N, C) stacked mid-walk carries
    y0: jax.Array,         # (B, N, C) stacked seed labels
    sigma: float,
    alpha,                 # traced scalar or (B,)
    n_iters: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
    divergence=None,
) -> jax.Array:
    """Batched LP segment from a carry: fold both operands, resume, unfold."""
    batch, _, c = y0.shape
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1:
        alpha = jnp.repeat(alpha, c)
    out = fused_lp_scan_folded_resume_kernel(
        x, fold_batch(y), fold_batch(y0), sigma, alpha, n_iters,
        block_m=block_m, block_n=block_n, interpret=interpret,
        divergence=divergence,
    )
    return unfold_batch(out, batch, c)
