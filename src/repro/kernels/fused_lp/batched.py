"""Batched fused Label-Propagation step Pallas kernel (TPU).

One device dispatch computes, for a stack of ``batch`` independent label
matrices over the SAME point set,

    out[b] = alpha * row_softmax(-||x_i - x_j||^2 / (2 sigma^2), zero diag) @ Y[b]
             + (1 - alpha) * Y0[b]

i.e. a full eq.-15 LP update fused with the exact streaming transition
matvec, never materializing the (N, N) matrix P.  This is the multi-user
serving shape: one fitted model, many concurrent propagation problems.

Grid: (batch, M/bm rows, N/bn cols), cols innermost.  As in the single-RHS
kernel (``fused_lp.py``), VMEM scratch carries the running max m, normalizer
s and weighted accumulator acc across column tiles; the last column tile
applies the fused axpy epilogue ``alpha * acc / s + (1 - alpha) * y0`` and
writes out.  Scratch is re-initialized at every (b, i) pair since the column
axis is the fastest-varying grid dimension.

``alpha=1.0`` degenerates to a plain batched matvec (the ``(1-alpha) * Y0``
term vanishes), which is how ``ops.fused_lp_matvec_batched`` calls it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_lp.fused_lp import NEG_BIG, stream_tile_update

__all__ = ["fused_lp_step_batched_kernel"]


def _kernel(rows_ref, cols_ref, y_ref, y0_ref, o_ref, m_ref, s_ref, acc_ref,
            *, inv_two_sigma_sq: float, alpha: float, n_valid: int,
            block_m: int, block_n: int):
    i = pl.program_id(1)
    j = pl.program_id(2)
    ncols = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    stream_tile_update(rows_ref, cols_ref, y_ref[0], m_ref, s_ref, acc_ref,
                       i, j, inv_two_sigma_sq=inv_two_sigma_sq,
                       n_valid=n_valid, block_m=block_m, block_n=block_n)

    @pl.when(j == ncols - 1)
    def _finish():
        py = acc_ref[...] / jnp.maximum(s_ref[...], 1e-38)[:, None]
        out = alpha * py + (1.0 - alpha) * y0_ref[0].astype(jnp.float32)
        o_ref[...] = out[None].astype(o_ref.dtype)


def fused_lp_step_batched_kernel(
    x: jax.Array,          # (N, d)   shared points
    y: jax.Array,          # (B, N, C) stacked current label matrices
    y0: jax.Array,         # (B, N, C) stacked seed label matrices
    sigma: float,
    alpha: float = 1.0,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """alpha * P @ Y[b] + (1-alpha) * Y0[b] for every b, P never materialized."""
    n, d = x.shape
    batch, _, c = y.shape
    mp = -(-n // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    xp_rows = jnp.pad(x, ((0, mp - n), (0, 0)))
    xp_cols = jnp.pad(x, ((0, np_ - n), (0, 0)))
    yp = jnp.pad(y, ((0, 0), (0, np_ - n), (0, 0)))
    y0p = jnp.pad(y0, ((0, 0), (0, mp - n), (0, 0)))

    kern = functools.partial(
        _kernel,
        inv_two_sigma_sq=float(1.0 / (2.0 * sigma * sigma)),
        alpha=float(alpha),
        n_valid=n, block_m=block_m, block_n=block_n,
    )
    out = pl.pallas_call(
        kern,
        grid=(batch, mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda b, i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda b, i, j: (j, 0)),
            pl.BlockSpec((1, block_n, c), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, c), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, c), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, mp, c), y.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m,), jnp.float32),
            pltpu.VMEM((block_m, c), jnp.float32),
        ],
        interpret=interpret,
    )(xp_rows, xp_cols, yp, y0p)
    return out[:, :n]
