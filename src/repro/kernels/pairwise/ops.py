"""jit'd public wrapper: Pallas on TPU, interpret-mode elsewhere."""
import functools

import jax

from repro.kernels.pairwise.pairwise import pairwise_sq_dists_kernel

__all__ = ["pairwise_sq_dists"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pairwise_sq_dists(x, y, block_m: int = 256, block_n: int = 256):
    return pairwise_sq_dists_kernel(
        x, y, block_m=block_m, block_n=block_n, interpret=not _on_tpu())
