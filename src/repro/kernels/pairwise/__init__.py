from repro.kernels.pairwise.ops import pairwise_sq_dists
from repro.kernels.pairwise.ref import pairwise_sq_dists_ref

__all__ = ["pairwise_sq_dists", "pairwise_sq_dists_ref"]
