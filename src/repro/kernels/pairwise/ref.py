"""Pure-jnp oracle for the pairwise squared-distance kernel."""
import jax
import jax.numpy as jnp

__all__ = ["pairwise_sq_dists_ref"]


@jax.jit
def pairwise_sq_dists_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
    return jnp.maximum(d2, 0.0)
