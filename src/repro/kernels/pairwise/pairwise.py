"""Tiled pairwise squared-distance Pallas kernel (TPU).

Computes D2[i, j] = ||x_i - y_j||^2 for x (M, d), y (N, d) as
``xx + yy - 2 x.y^T``: the cross term hits the MXU as a (bm, d) x (d, bn)
matmul per tile; the norm terms are rank-1 VPU adds.  Tiles are MXU-aligned
(128-multiples); the d (contraction) dimension stays whole in VMEM — for the
paper's workloads d <= 1156 so a (256, 1156) f32 tile is ~1.2 MB, well under
the ~16 MB VMEM budget for the 3 live tiles.

This is the build-time hot spot of both baselines (kNN graph construction
and the exact transition matrix) in the paper's §5 comparisons.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_sq_dists_kernel", "pairwise_sq_dists"]


def _kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)      # (bm, d)
    y = y_ref[...].astype(jnp.float32)      # (bn, d)
    xx = jnp.sum(x * x, axis=-1)            # (bm,)
    yy = jnp.sum(y * y, axis=-1)            # (bn,)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = xx[:, None] + yy[None, :] - 2.0 * xy
    o_ref[...] = jnp.maximum(d2, 0.0)


def pairwise_sq_dists_kernel(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(M, d), (N, d) -> (M, N) squared distances via pl.pallas_call."""
    m, d = x.shape
    n = y.shape[0]
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    yp = jnp.pad(y, ((0, np_ - n), (0, 0)))

    out = pl.pallas_call(
        _kernel,
        grid=(mp // block_m, np_ // block_n),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


pairwise_sq_dists = functools.partial(pairwise_sq_dists_kernel, interpret=False)
