"""Causal GQA flash-attention Pallas kernel (TPU).

Online-softmax attention that never materializes the (S, S) score matrix —
the VMEM working set is (bq, d) + (bk, d) + (bq, bk).  Supports grouped
query heads (kv head = q head // group) and an optional sliding window.

Grid: (batch, q_heads, Sq/bq, Skv/bk) with the kv dimension innermost;
scratch (m, s, acc) carries the online softmax across kv tiles.  Causal
lower-triangular structure: tiles entirely above the diagonal contribute
nothing and are masked (on real TPU runs the index-map based revisiting
still walks them; the §Perf log quantifies the win of halving the grid with
a triangular schedule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel"]

_NEG_BIG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, s_ref, acc_ref,
            *, scale: float, block_q: int, block_k: int, window: int,
            causal: bool):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_BIG)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 0)
    kj = jk * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                 (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= (qi - kj) < window
    logits = jnp.where(mask, logits, _NEG_BIG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    s_ref[...] = s_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(s_ref[...], 1e-38)[:, None]).astype(
                           o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,          # (B, Hq, S, d)
    k: jax.Array,          # (B, Hkv, S, d)
    v: jax.Array,          # (B, Hkv, S, d)
    *,
    causal: bool = True,
    window: int = 0,       # 0 = no sliding window
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    sp = -(-s // block_q) * block_q
    spk = -(-s // block_k) * block_k
    assert sp == spk or True
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, spk - s), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, spk - s), (0, 0)))

    kern = functools.partial(
        _kernel, scale=float(d) ** -0.5, block_q=block_q, block_k=block_k,
        window=window, causal=causal)

    out = pl.pallas_call(
        kern,
        grid=(b, hq, sp // block_q, spk // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s, :]
