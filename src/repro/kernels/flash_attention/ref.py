"""Pure-jnp oracle: naive masked attention with materialized scores."""
import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= (qi - kj) < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
