"""Optimizers implemented in-repo (no external deps): AdamW with decoupled
weight decay and learning-rate schedules (warmup + cosine)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(leaf.astype(jnp.float32) ** 2)
                        for leaf in leaves))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = warmup_cosine(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), metrics
