"""Train step: next-token cross-entropy, microbatched gradient accumulation
(compute/comm overlap: the gradient all-reduce is deferred to the end of the
accumulation loop), mixed precision, optional chunked-vocab loss."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.transformer import lm_forward
from repro.models.whisper import encdec_forward
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)

__all__ = ["TrainState", "init_train_state", "make_train_step", "lm_loss"]


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def init_train_state(params, opt_cfg: AdamWConfig) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def _ce(logits: jax.Array, labels: jax.Array, vocab: int,
        chunked: int = 0) -> jax.Array:
    """Mean next-token CE.  ``chunked``>0 scans over sequence chunks so the
    (B, S, V) f32 softmax intermediate never materializes at once."""
    if chunked:
        b, s, v = logits.shape
        nc = s // chunked

        def body(acc, i):
            lg = jax.lax.dynamic_slice_in_dim(logits, i * chunked, chunked, 1)
            lb = jax.lax.dynamic_slice_in_dim(labels, i * chunked, chunked, 1)
            ls = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(ls, lb[..., None], -1).sum()
            return acc + nll, None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(nc))
        return tot / (b * s)
    ls = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(ls, labels[..., None], -1)
    return nll.mean()


def lm_loss(params, batch: dict, cfg, aux_weight: float = 0.01,
            chunked_ce: int = 0):
    """batch: {"tokens": (B, S+1)} (+ optional "patches"/"frames")."""
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    if cfg.family == "audio":
        logits, aux = encdec_forward(params, inp, batch["frames"], cfg)
    elif cfg.family == "vlm":
        logits, aux = lm_forward(params, inp, cfg, patches=batch["patches"])
        logits = logits[:, cfg.n_patches:]          # score text positions only
    else:
        logits, aux = lm_forward(params, inp, cfg)
    loss = _ce(logits, labels, cfg.padded_vocab, chunked=chunked_ce)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


def make_train_step(cfg, opt_cfg: AdamWConfig, n_microbatches: int = 1,
                    chunked_ce: int = 0):
    """Returns train_step(state, batch) -> (state, metrics).

    With ``n_microbatches > 1`` the global batch is split and gradients are
    accumulated in f32; the (FSDP/DP) gradient reduction happens once, after
    the loop — this is the compute/comm overlap knob measured in §Perf.
    """

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, chunked_ce=chunked_ce)

    def train_step(state: TrainState, batch: dict):
        if n_microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def micro(i):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(n_microbatches,
                                        x.shape[0] // n_microbatches,
                                        *x.shape[1:])[i],
                    batch,
                )

            def body(carry, i):
                acc, loss_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, micro(i))
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(n_microbatches))
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = {"ce": loss, "aux": jnp.zeros(())}

        params, opt, opt_metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step
