"""Pluggable queue-discipline layer for the continuous-batching engine.

A condition-variable wrapper around an ordered container, purpose-built for
the scheduler's access pattern:

* producers (``PropagateEngine.submit``) ``put`` one entry, either failing
  fast (``QueueFull``) or blocking until space frees — the engine's
  backpressure;
* the single scheduler consumer waits for the queue to go non-empty
  (``wait_nonempty``) and then ``drain``\\ s up to a whole microbatch in one
  lock acquisition, skipping entries whose future was already cancelled.

``stdlib queue.Queue`` fits none of this: no multi-item atomic drain, no
cancellation filtering, and its unfinished-task accounting is dead weight
here.

Queue disciplines (scheduler v2)
--------------------------------
``discipline`` selects the order ``drain`` pops entries in:

``"fifo"`` (default)
    Submission order — bit-identical to the original single-discipline
    queue (a plain deque; ``drain`` is ``popleft``).

``"priority"``
    Highest :attr:`QueueEntry.priority` first, with **starvation-bounded
    aging**: an entry's effective rank is ``priority - t_submit /
    aging_s``, so every second spent waiting is worth ``1 / aging_s``
    priority levels.  Two consequences, both deterministic because the
    rank is a static function of ``(priority, t_submit)``: entries of
    equal priority stay FIFO among themselves, and a default-priority
    entry outranks any higher-priority entry submitted more than
    ``aging_s * (priority gap)`` later — no entry can be starved for
    longer than that bound (plus one service round).

``"edf"``
    Earliest-deadline-first: smallest absolute :attr:`QueueEntry.t_deadline`
    first; entries without a deadline sort after every deadlined one, FIFO
    among themselves.  ``drain`` additionally **fast-fails expired
    entries**: anything already past its deadline is returned in the
    ``expired`` list instead of ``live``, so a dispatch slot is never spent
    computing an answer whose deadline has passed (the engine resolves
    those futures with :class:`DeadlineExceeded`).

Time comes from the injectable ``clock`` (default
``time.perf_counter``) — aging ranks and expiry checks are deterministic
under a fake clock, which is how the scheduler property tests drive this
layer.

Concurrency contract
--------------------
All methods are thread-safe; any number of producer threads may ``put``
concurrently.  The design assumes a SINGLE consumer (the engine's
scheduler): ``wait_nonempty``/``wait_atleast`` + ``drain`` are only
race-free in the sense that one consumer sees every entry exactly once —
two concurrent drainers would simply split the backlog between them.
Cancellation is cooperative: cancelling an entry's future while it is
queued guarantees it never reaches a dispatch (the next ``drain`` discards
it), but cancellation after a drain has returned the entry is the
dispatcher's problem (see ``PropagateEngine._dispatch``).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Optional

__all__ = [
    "DISCIPLINES",
    "DeadlineExceeded",
    "QueueEntry",
    "QueueFull",
    "RequestQueue",
]

DISCIPLINES = ("fifo", "priority", "edf")

# rank gained per second of waiting under the "priority" discipline; see
# RequestQueue for the starvation bound it implies
DEFAULT_AGING_S = 0.5


class QueueFull(RuntimeError):
    """Raised by a non-blocking ``put`` when the queue is at capacity."""


class DeadlineExceeded(RuntimeError):
    """An EDF request expired before its dispatch started.

    Pinned API: futures of expired entries resolve with exactly this
    exception type, so clients can catch it and shed/retry — it never
    degrades into a generic ``RuntimeError`` or a silent late answer.
    """


@dataclasses.dataclass
class QueueEntry:
    """A submitted request riding through the scheduler."""

    seq: int  # submission order, for deterministic tie-breaks
    request: object  # PropagateRequest
    future: Future  # resolved by the dispatch that serves it
    t_submit: float  # clock() at accept, for latency metrics + aging
    priority: int = 0  # larger = more urgent ("priority" discipline)
    t_deadline: Optional[float] = None  # absolute clock() deadline ("edf")
    epoch: int = 0  # fitted-model epoch pinned at submit: the entry is
    #   dispatched against exactly this epoch's tree even if a streaming
    #   publish lands while it is queued (see PropagateEngine.publish)


class RequestQueue:
    """Bounded request queue with a pluggable pop-order discipline.

    ``drain`` atomically pops up to a microbatch in discipline order with
    cancel filtering (and, under ``"edf"``, expiry fast-fail); ``put``
    blocks or raises :class:`QueueFull` — the backpressure surface.
    """

    def __init__(
        self,
        maxsize: int,
        discipline: str = "fifo",
        *,
        aging_s: float = DEFAULT_AGING_S,
        clock: Callable[[], float] = time.perf_counter,
    ):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        if discipline not in DISCIPLINES:
            raise ValueError(f"discipline must be one of {DISCIPLINES}, got {discipline!r}")
        if aging_s <= 0:
            raise ValueError(f"aging_s must be > 0, got {aging_s}")
        self.maxsize = maxsize
        self.discipline = discipline
        self.aging_s = float(aging_s)
        self._clock = clock
        # fifo keeps the original deque (bit-identical behavior); the other
        # disciplines keep a heap of (sort key, seq, entry) triples — both
        # ranks are static functions of the entry, so heap order is exact
        self._fifo: deque[QueueEntry] = deque()
        self._heap: list[tuple[float, int, QueueEntry]] = []
        # lifetime pops (live + cancelled + expired): lets a consumer bound
        # "drain what was queued at time T" without racing fresh producers
        # (PropagateEngine.flush snapshots this against len())
        self._popped = 0
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)

    def _key(self, entry: QueueEntry) -> float:
        """Heap sort key (smaller pops first) — static per entry."""
        if self.discipline == "priority":
            # effective rank priority - t_submit/aging_s, highest first:
            # waiting 1 * aging_s is worth one priority level, so the rank
            # gap between an old low-priority entry and newer high-priority
            # traffic closes at a fixed, clock-driven rate
            return -(entry.priority - entry.t_submit / self.aging_s)
        # edf: earliest absolute deadline first; deadline-less entries last
        return entry.t_deadline if entry.t_deadline is not None else float("inf")

    def __len__(self) -> int:
        with self._lock:
            return len(self._fifo) + len(self._heap)

    def _size_locked(self) -> int:
        return len(self._fifo) + len(self._heap)

    def put(self, entry: QueueEntry, block: bool = True, timeout: Optional[float] = None) -> None:
        """Append ``entry``; raise :class:`QueueFull` if no space appears.

        ``block=False`` fails fast at capacity; ``block=True`` waits until a
        drain frees space, up to ``timeout`` seconds (``None`` = forever).
        This is the engine's backpressure surface: a saturated engine makes
        producers either slow down (blocking) or shed load (QueueFull).
        """
        with self._not_full:
            if self._size_locked() >= self.maxsize:
                if not block:
                    raise QueueFull(f"queue at capacity ({self.maxsize}); retry or raise max_queue")
                has_room = lambda: self._size_locked() < self.maxsize  # noqa: E731
                if not self._not_full.wait_for(has_room, timeout=timeout):
                    raise QueueFull(f"queue still full after {timeout}s; engine saturated")
            if self.discipline == "fifo":
                self._fifo.append(entry)
            else:
                heapq.heappush(self._heap, (self._key(entry), entry.seq, entry))
            self._not_empty.notify()

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Block until at least one entry is queued (or timeout); True if so."""
        with self._not_empty:
            return self._not_empty.wait_for(lambda: self._size_locked() > 0, timeout=timeout)

    def wait_atleast(self, n: int, timeout: Optional[float] = None) -> bool:
        """Block until ``>= n`` entries are queued (or timeout); True if so.

        The scheduler's batching window: after the first request of an
        iteration lands, linger briefly for the batch to fill before
        dispatching a partial one.
        """
        with self._not_empty:
            return self._not_empty.wait_for(lambda: self._size_locked() >= n, timeout=timeout)

    def next_deadline(self) -> Optional[float]:
        """Smallest absolute deadline currently queued (``edf`` only).

        The engine's linger caps its batching window at this instant so
        waiting for a fuller batch can never itself expire the most urgent
        request.  ``None`` when no queued entry carries a deadline.
        """
        with self._lock:
            if self.discipline != "edf" or not self._heap:
                return None
            key = self._heap[0][0]
            return key if key != float("inf") else None

    def deadline_before(self, horizon: float) -> bool:
        """True iff some queued entry's deadline falls before ``horizon``.

        The peek-urgency predicate behind preemptible dispatch: between
        scan segments the engine asks "would anything queued expire before
        the in-flight work finishes?" — a cheap O(1) heap peek, never a
        pop.  Always ``False`` outside the ``edf`` discipline (no deadline
        order to consult).
        """
        nearest = self.next_deadline()
        return nearest is not None and nearest < horizon

    @property
    def popped(self) -> int:
        """Monotone count of entries ever popped (live, cancelled, expired)."""
        with self._lock:
            return self._popped

    def _pop_locked(self) -> QueueEntry:
        if self.discipline == "fifo":
            return self._fifo.popleft()
        return heapq.heappop(self._heap)[2]

    def drain(self, max_items: int) -> tuple[list[QueueEntry], list[QueueEntry], list[QueueEntry]]:
        """Atomically pop up to ``max_items`` live entries in discipline order.

        Returns ``(live, cancelled, expired)``: entries whose future was
        cancelled while queued never reach a dispatch, and — under the
        ``"edf"`` discipline — entries already past their deadline are
        fast-failed into ``expired`` instead of wasting a dispatch slot.
        Both still free queue capacity and don't count against
        ``max_items``.
        """
        live: list[QueueEntry] = []
        cancelled: list[QueueEntry] = []
        expired: list[QueueEntry] = []
        now = self._clock() if self.discipline == "edf" else 0.0
        with self._not_full:
            while self._size_locked() and len(live) < max_items:
                entry = self._pop_locked()
                if entry.future.cancelled():
                    cancelled.append(entry)
                    continue
                if (
                    self.discipline == "edf"
                    and entry.t_deadline is not None
                    and now > entry.t_deadline
                ):
                    expired.append(entry)
                    continue
                live.append(entry)
            self._popped += len(live) + len(cancelled) + len(expired)
            if live or cancelled or expired:
                self._not_full.notify_all()
        return live, cancelled, expired

    def drain_urgent(
        self, max_items: int, horizon: float
    ) -> tuple[list[QueueEntry], list[QueueEntry], list[QueueEntry]]:
        """Atomically pop only entries whose deadline falls before ``horizon``.

        The preemption drain: when a suspended scan yields at a segment
        boundary, the engine serves exactly the requests that could not
        have survived waiting for it — entries with ``t_deadline <
        horizon`` — and leaves everything else queued in discipline order
        for the normal scheduler pass.  The ``edf`` heap is deadline-
        ordered, so this is a prefix pop that stops at the first
        non-urgent entry.  Returns ``(live, cancelled, expired)`` exactly
        like :meth:`drain`; empty lists outside the ``edf`` discipline.
        """
        live: list[QueueEntry] = []
        cancelled: list[QueueEntry] = []
        expired: list[QueueEntry] = []
        if self.discipline != "edf":
            return live, cancelled, expired
        now = self._clock()
        with self._not_full:
            while self._heap and len(live) < max_items:
                key = self._heap[0][0]
                if key == float("inf") or key >= horizon:
                    break
                entry = heapq.heappop(self._heap)[2]
                if entry.future.cancelled():
                    cancelled.append(entry)
                    continue
                if entry.t_deadline is not None and now > entry.t_deadline:
                    expired.append(entry)
                    continue
                live.append(entry)
            self._popped += len(live) + len(cancelled) + len(expired)
            if live or cancelled or expired:
                self._not_full.notify_all()
        return live, cancelled, expired
