"""Public serving API: one blessed import surface for the whole tier.

Everything a serving user needs imports from HERE::

    from repro.serving import EngineFleet, PropagateEngine, PropagateRequest

The layers underneath:

* :class:`Engine` / :class:`FitParams` / :class:`DispatchState` /
  :class:`ResultSlab` — the abstract engine contract
  (:mod:`repro.serving.engine_api`): params/state separation, slot-based
  result slabs, the lifecycle every engine implements.
* :class:`PropagateEngine` — the continuous-batching engine over one
  fitted variational dual tree (the first :class:`Engine` implementation).
* :class:`ShardedPropagateEngine` — the same engine contract executed
  SPMD across a device mesh (leaf-order rows sharded, per-iteration
  matvec collective); bit-identical outputs, discoverable via
  ``Engine.capabilities()`` (``"sharded"``).
* :class:`EngineFleet` / :class:`FleetMetricsSnapshot` — the multi-tenant
  front-end: tenant -> fitted tree -> engine routing with weighted
  deficit-round-robin fair queueing.
* :func:`propagate_many` — static-list batching over one fitted tree.
* :class:`PropagateRequest` — the one request type every entry point
  accepts; :class:`QueueFull` / :class:`DeadlineExceeded` — the
  backpressure / deadline exceptions; :class:`MetricsSnapshot` — per-engine
  observability.

The historical deep modules (``repro.serving.engine``,
``repro.serving.propagate``, ``repro.serving.queue``,
``repro.serving.metrics``) still import but are deprecated shims over the
private ``_*`` implementation modules; new code should import from this
package directly.  ``tools/check_api.py`` pins this surface against
``tests/api_snapshot.json`` in CI.
"""
from repro.serving._batching import (DEFAULT_WIDTH_BUCKETS, PropagateRequest)
from repro.serving._engine import PropagateEngine
from repro.serving._sharded import ShardedPropagateEngine
from repro.serving._metrics import MetricsSnapshot
from repro.serving._propagate import propagate_many
from repro.serving._queue import DeadlineExceeded, QueueFull
from repro.serving.engine_api import (DispatchState, Engine, FitParams,
                                      ResultSlab)
from repro.serving.fleet import EngineFleet, FleetMetricsSnapshot

__all__ = [
    "DEFAULT_WIDTH_BUCKETS",
    "DeadlineExceeded",
    "DispatchState",
    "Engine",
    "EngineFleet",
    "FitParams",
    "FleetMetricsSnapshot",
    "MetricsSnapshot",
    "PropagateEngine",
    "PropagateRequest",
    "QueueFull",
    "ResultSlab",
    "ShardedPropagateEngine",
    "propagate_many",
]
