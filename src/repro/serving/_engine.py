"""Continuous-batching async LP serving engine over one fitted VDT.

:class:`PropagateEngine` is the dynamic counterpart of
:func:`~repro.serving.propagate.propagate_many`: instead of batching a
static request list, it owns a live bounded queue and a scheduler that
coalesces *whatever is waiting* into few batched device dispatches, while
clients block on per-request futures.

Scheduling policy (scheduler v2)
--------------------------------
One scheduler iteration (``step`` when driven manually, the background
thread's loop body otherwise):

1. wait for the queue to go non-empty, then linger for it to fill toward
   ``max_batch`` — the classic throughput/latency batching window.  The
   window is **rate-adaptive**: an EWMA of observed inter-arrival gaps
   estimates how long ``max_batch`` arrivals take, and the linger waits
   ``min(max_wait_ms, ewma_gap * missing_slots)`` (clamped to
   ``[0, max_wait_ms]``; under ``policy="edf"`` additionally capped at the
   earliest queued deadline, so batching can never itself expire the most
   urgent request).  The linger also ends as soon as arrivals quiesce for
   ~1ms, so a lone request never waits the full window.  All timing runs
   on the injectable ``clock``, so tests drive it deterministically;
2. atomically drain up to ``max_batch`` entries **in queue-discipline
   order** (``policy``: FIFO, priority with starvation-bounded aging, or
   earliest-deadline-first — see ``serving/queue.py``), dropping entries
   whose future was cancelled while queued and fast-failing expired EDF
   entries with :class:`DeadlineExceeded` before they cost a dispatch;
3. group the drained entries by ``(n_iters, backend)`` — only requests
   sharing a scan length and a transition matrix can share a dispatch.
   ``backend`` is **per-request** (exact/VDT hybrid routing, resolved at
   submit via :func:`repro.core.label_prop.route_backend`), so validation
   or small-N traffic tagged ``backend="exact"`` rides the same engine as
   bulk VDT traffic without fragmenting either side's batches.  Alpha does
   NOT fragment groups — LP is column-independent, so each request's alpha
   rides the dispatch as one element of a *traced* per-request array (see
   ``VariationalDualTree.label_propagate``).  Width does not fragment
   either by default (``coalesce_widths=True``): every request in the
   group is zero-padded to the group's largest width bucket, because one
   ``lax.scan`` dispatch has a large fixed cost (hundreds of per-iteration
   op launches) and a small per-column marginal cost, so one fat dispatch
   beats several narrow ones on CPU/GPU.  ``coalesce_widths=False``
   restores per-width-bucket grouping (the ``propagate_many`` policy) for
   backends where compute scales hard with padded width;
4. per group, zero-pad widths to the chosen bucket, pad the batch axis to
   the next power of two (with zero rows at alpha 0), run one batched
   ``label_propagate`` on the group's backend, slice each answer back to
   its true width, and resolve the futures (counting completions that
   landed after their request's deadline as ``deadline_missed``).

Backends
--------
``"vdt"`` (the default) serves the fitted O(|B|) approximation — the
production path.  ``"exact"`` serves the exact eq.-3 matrix through the
distance-reusing fused kernel (``core.label_prop.lp_scan_fused``): the
coalesced group shares one streaming pass per LP iteration, so the
pairwise-distance/softmax work — the reason exact LP was ever expensive to
batch — is paid once per iteration for the whole group instead of once per
request.  ``"grf"`` serves the graph-random-features walker estimator
(``core/grf.py``): an unbiased Monte-Carlo estimate of the same eq.-15
walk whose per-iteration cost is O(N * n_walkers), with the walker budget
as a per-request accuracy dial (explicit ``n_walkers``, or CLT-sized from
``rtol``) — grf groups dispatch at the max budget over their members and
always monolithically (no resume primitive), deterministically per
``grf_seed``.  The engine-level ``backend`` is only the *default*: each
``PropagateRequest(backend=...)`` may override it (``"exact"`` for
accuracy-validation traffic, ``"auto"`` for route-by-size), making one
engine a multi-backend hybrid.

Preemptible dispatch
--------------------
Without it, EDF only reorders the *queue*: a deadline-100ms request
arriving one segment into a 500-iteration bulk scan still waits out the
whole scan — head-of-line blocking behind in-flight work — and fast-fails
on expiry despite the device having had plenty of boundary opportunities
to serve it.  ``segment_iters=k`` (with ``policy="edf"``) fixes this:
scans longer than ``k`` run as resumable ``k``-iteration segments
(``VariationalDualTree.label_propagate_resume``; bit-identical to the
monolithic scan, since eq. 15 is a pure fixed-point iteration and the
carry plus the seed is the walk's complete state).  Between segments the
scheduler re-checks the queue: if any queued deadline falls before ``now +
est_iter_time * iters_remaining`` (per-iteration EWMA of measured segment
times), the walk yields — urgent entries drain (deadline-ordered prefix of
the EDF heap, everything else stays queued) and dispatch *now*,
non-preemptibly, then the suspended scan resumes from its carry.  Worst-
case added latency for an urgent arrival drops from ``O(n_iters)`` to one
segment: ``preempt_latency <= segment_iters * iter_time + urgent dispatch
cost``.  ``metrics()`` exposes ``preemptions`` (boundary yields) and
``preempt_iters`` (iterations still pending at those yields); the
``preempt`` benchmark scenario measures the p95 urgent-arrival latency
under exactly this contention and the bench gate caps it.

Compile-cache bound
-------------------
Jitted executables are keyed by ``(n_iters, N, batch bucket * width
bucket)`` — plus the *backend* and, for the exact backend, the fitted
*divergence* (a static jit argument of the fused kernels), so engines
serving different Bregman divergences compile disjoint executables and can
never cross-contaminate each other's cache.  Each engine's
``metrics().dispatch_key`` reports its default ``backend:divergence``
identity.  Width buckets come from the shared ``buckets`` tuple and batch
buckets are powers of two up to ``max_batch``, so steady-state traffic
touches at most ``backends * len(buckets) * log2(max_batch)`` executables
per ``n_iters`` — whatever widths, alphas, and arrival orders users
produce.  ``n_iters`` itself is a static scan length, NOT bucketed
(changing it changes the math): a deployment should pin it to a small
recipe set, since every distinct value compiles its own executable grid.

Buffer reuse
------------
The engine keeps one pinned host staging buffer per ``(batch bucket, width
bucket)`` and refills it in place each scheduler iteration, and the fitted
tree's dispatch buffers (block indices, ``exp(log_q)``, leaf mask) are
cached device-side on the ``VariationalDualTree`` itself — steady-state
iterations allocate nothing on the host path.

Concurrency contract
--------------------
``submit`` is thread-safe and may be called from any thread (or wrapped for
asyncio via ``asyncio.wrap_future(engine.submit(req))`` — see
``examples/lp_engine_async.py``).  Exactly one scheduler drives dispatches:
the background thread (``start=True``) or the caller of ``step``/``flush``
(``start=False``, the deterministic mode the unit tests use).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.label_prop import route_backend
from repro.serving._batching import (DEFAULT_WIDTH_BUCKETS, PropagateRequest,
                                     batch_bucket, bucket_width,
                                     dispatch_group_key)
from repro.serving._metrics import EngineMetrics, MetricsSnapshot
from repro.serving._queue import (DISCIPLINES, DeadlineExceeded, QueueEntry,
                                  QueueFull, RequestQueue)
from repro.serving.engine_api import (DispatchState, Engine, FitParams,
                                      ResultSlab)

__all__ = ["PropagateEngine", "QueueFull", "DeadlineExceeded",
           "PropagateRequest"]


_log = logging.getLogger(__name__)


@dataclasses.dataclass
class _Epoch:
    """One published model version and its serving refcount.

    ``pending`` counts entries accepted at this epoch that have not yet
    reached a terminal state (result, failure, cancel, expiry).  A
    non-current epoch whose pending count drains to zero is *retired*:
    its record — and with it the pinned model and ``FitParams`` — is
    dropped, and staging buffers sized for a point count no live epoch
    uses are pruned by the scheduler.  The current epoch is never retired.
    """

    eid: int
    vdt: object  # the fitted VariationalDualTree this epoch serves
    n: int  # its point count (the request-shape contract at this epoch)
    divergence: str
    fit_params: FitParams
    pending: int = 0


@dataclasses.dataclass
class _InFlightScan:
    """A segmented group dispatch suspended (or running) mid-walk.

    The resumable in-flight record behind preemptible dispatch: eq. 15 is
    a pure fixed-point iteration, so ``carry`` after ``iters_done`` steps
    plus the seed ``y0`` is the COMPLETE state of the walk — resuming from
    it (``VariationalDualTree.label_propagate_resume``) is bit-identical
    to never having paused.  The engine holds one of these per segmented
    group; between segments it re-checks the queue and, if an urgent
    arrival's deadline would expire before the remaining
    ``n_iters - iters_done`` iterations complete, yields the device to an
    urgent dispatch before resuming.
    """

    entries: list  # the group's QueueEntry list, batch-slot order
    carry: object  # (bb, N, cb) device array: the walk state so far
    y0: object  # (bb, N, cb) device array: seed labels (eq.-15 restart term)
    alphas: object  # (bb,) per-request alpha (padding rows: 0)
    n_iters: int
    backend: str
    iters_done: int = 0


class PropagateEngine(Engine):
    """Async continuous-batching server for LP requests on one fitted VDT.

    The first concrete implementation of the abstract
    :class:`~repro.serving.engine_api.Engine` contract: ``fit_params`` is
    the fitted ``VariationalDualTree`` (immutable, shareable), and
    ``dispatch_state`` (queue + staging pool + metrics sink) is owned by
    whichever single scheduler drives ``step``/``flush`` — the background
    thread (``start=True``), a test, or an
    :class:`~repro.serving.fleet.EngineFleet` serving this engine as one
    tenant.

    Parameters
    ----------
    vdt:         the fitted ``VariationalDualTree`` all requests run against.
    max_batch:   most requests coalesced into one device dispatch.
    max_wait_ms: cap on how long the scheduler lingers for a fuller batch
                 once the first request of an iteration has arrived; the
                 adaptive policy picks the actual window per iteration
                 (0 disables lingering entirely).
    max_queue:   bounded-queue capacity; ``submit`` beyond it blocks or
                 raises :class:`QueueFull` (backpressure).
    buckets:     label-width buckets, shared with ``propagate_many``.
    coalesce_widths: pad a whole group to its largest width bucket so mixed
                 widths share one dispatch (default; see module docstring).
    backend:     default transition-matrix backend — ``"vdt"`` (fitted
                 approximation), ``"exact"`` (streamed exact P via the
                 distance-reusing fused kernel), ``"grf"`` (the
                 Monte-Carlo walker estimator over the fitted kernel
                 graph) or ``"auto"`` (exact for small N; never grf on an
                 engine, whose complete kernel graph is dense).
                 Individual requests may override it; see *Backends* in
                 the module docstring.
    n_walkers:   default grf walker budget per dispatch.  A grf group
                 dispatches at the max over its members' budgets (an
                 explicit ``PropagateRequest.n_walkers``, else the CLT
                 sizing ``walkers_for_rtol(rtol)`` when the request
                 states an accuracy target, else this default) — walker
                 count never fragments a batch, mirroring width
                 coalescing.  ``metrics().n_walkers`` reports the budget
                 of the most recent grf dispatch.
    grf_seed:    PRNG seed for grf dispatches.  Together with the pinned
                 epoch's model it fully determines the walks, so repeated
                 dispatches of the same group are bit-identical — the
                 same determinism contract the other backends get for
                 free.  grf scans never segment (no resume primitive for
                 a Monte-Carlo series), so they dispatch monolithically
                 even under ``policy="edf"`` + ``segment_iters``.
    policy:      queue discipline — ``"fifo"`` (default, submission order),
                 ``"priority"`` (highest ``PropagateRequest.priority``
                 first with starvation-bounded aging) or ``"edf"``
                 (earliest ``deadline_ms`` first, expired requests
                 fast-fail with :class:`DeadlineExceeded`).
    aging_ms:    the ``"priority"`` discipline's starvation bound: waiting
                 ``aging_ms`` is worth one priority level, so a
                 default-priority request is never overtaken by
                 higher-priority traffic submitted more than
                 ``aging_ms * (priority gap)`` after it.
    adaptive_linger: scale the batching window by the observed arrival
                 rate (EWMA of inter-arrival gaps) instead of always
                 lingering toward ``max_wait_ms``.
    segment_iters: preemptible dispatch — split every LP scan longer than
                 this into ``segment_iters``-sized resumable segments and
                 re-check the queue at each boundary (see *Preemptible
                 dispatch* in the module docstring).  ``None`` (default)
                 dispatches monolithically.  Only effective under
                 ``policy="edf"``: the other disciplines carry no deadline
                 signal, so there is nothing to preempt for.
    clock:       monotonic time source (seconds).  Injectable so the
                 scheduler's timing decisions — linger windows, aging
                 ranks, deadline expiry, latency metrics — are
                 deterministic under test fake clocks instead of
                 wall-clock-flaky on loaded CI runners.
    start:       spawn the background scheduler thread.  ``start=False``
                 leaves scheduling to explicit ``step``/``flush`` calls —
                 deterministic, single-threaded, what the unit tests drive.
    """

    def __init__(
        self,
        vdt,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 2.0,
        max_queue: int = 256,
        buckets: Sequence[int] = DEFAULT_WIDTH_BUCKETS,
        coalesce_widths: bool = True,
        backend: str = "vdt",
        n_walkers: int = 64,
        grf_seed: int = 0,
        policy: str = "fifo",
        aging_ms: float = 500.0,
        adaptive_linger: bool = True,
        segment_iters: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
        start: bool = True,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if policy not in DISCIPLINES:
            raise ValueError(
                f"policy must be one of {DISCIPLINES}, got {policy!r}")
        if segment_iters is not None and segment_iters < 1:
            raise ValueError(
                f"segment_iters must be >= 1 or None, got {segment_iters}")
        if n_walkers < 1:
            raise ValueError(f"n_walkers must be >= 1, got {n_walkers}")
        self.vdt = vdt
        self.n_walkers = int(n_walkers)
        self.grf_seed = int(grf_seed)
        self._last_n_walkers = 0  # gauge: budget of the latest grf dispatch
        self.n = int(vdt.tree.n_points)
        # the engine-level backend is the per-request DEFAULT; "auto"
        # resolves here against the fitted problem size (route_backend also
        # rejects unknown tags at construction, not at first dispatch)
        self.backend = route_backend(backend, "vdt", n=self.n)
        # divergence rides in the dispatch key: engines over different
        # fitted divergences never share a compiled executable (the exact
        # backend keys its kernels statically on the divergence; the VDT
        # backend's q encodes it as data), and the metrics snapshot exposes
        # the key so operators can tell mixed-divergence deployments apart
        self.divergence = vdt.divergence_name
        self.dispatch_key = f"{self.backend}:{self.divergence}"
        self.policy = policy
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.aging_ms = float(aging_ms)
        self.adaptive_linger = bool(adaptive_linger)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.coalesce_widths = bool(coalesce_widths)
        self._clock = clock
        self._queue = RequestQueue(max_queue, discipline=policy,
                                   aging_s=self.aging_ms / 1e3, clock=clock)
        self._metrics = EngineMetrics()
        self._seq = 0
        self._in_flight = 0
        self.segment_iters = None if segment_iters is None else int(segment_iters)
        # arrival-rate estimate feeding the adaptive linger window
        self._ewma_gap_s: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._linger_window_ms = float("nan")
        # per-LP-iteration device-time estimate (EWMA over completed
        # segments), feeding the preempt horizon: "would anything queued
        # expire before the remaining iterations finish?"
        self._ewma_iter_s: Optional[float] = None
        self._state_lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = False
        # host staging pool: (n_points, batch bucket, width bucket) -> np
        # buffer, refilled in place every scheduler iteration.  n_points is
        # part of the key because streaming publishes can change N; buffers
        # for point counts no live epoch uses are pruned by the scheduler
        # once the old epoch drains (_staging_dirty).
        self._staging: dict[tuple[int, int, int], np.ndarray] = {}
        self._staging_dirty = False
        self._thread: Optional[threading.Thread] = None
        # epoch-versioned model records: every queued entry pins the epoch
        # it was submitted under, so a publish() mid-flight never changes
        # the bits of already-accepted work (see publish)
        self._fit_params = FitParams(
            model=vdt, n_points=self.n, divergence=self.divergence, epoch=0)
        self._epoch_id = 0
        self._epochs: dict[int, _Epoch] = {0: _Epoch(
            eid=0, vdt=vdt, n=self.n, divergence=self.divergence,
            fit_params=self._fit_params)}
        self._stale_blocks = 0
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="propagate-engine", daemon=True)
            self._thread.start()

    # ------------------------------------------------- engine-api data halves
    def capabilities(self) -> frozenset[str]:
        """See :meth:`Engine.capabilities
        <repro.serving.engine_api.Engine.capabilities>`.

        The continuous-batching engine always publishes epochs and serves
        the grf walker backend; ``"preempt"`` is configuration-dependent —
        segmented dispatch only actually happens under ``policy="edf"``
        (the one discipline with an urgency signal) with ``segment_iters``
        set, so only that configuration reports it.
        """
        caps = {"publish", "grf"}
        if self.policy == "edf" and self.segment_iters is not None:
            caps.add("preempt")
        return frozenset(caps)

    @property
    def fit_params(self) -> FitParams:
        """The fitted tree + its serving identity (immutable, shareable)."""
        return self._fit_params

    @property
    def dispatch_state(self) -> DispatchState:
        """Live handles to the queue / staging pool / metrics sink.

        These are the engine's working structures (not copies) — the
        mutable half that exactly one scheduler may drive; see
        :class:`~repro.serving.engine_api.DispatchState`.
        """
        return DispatchState(queue=self._queue, staging=self._staging,
                             metrics=self._metrics)

    # -------------------------------------------------------------- warmup
    def warmup(self, widths: Optional[Sequence[int]] = None,
               n_iters: Sequence[int] = (500,),
               backends: Optional[Sequence[str]] = None) -> int:
        """Pre-compile every dispatch executable this traffic can reach.

        The scheduler only ever issues shapes ``(batch bucket, N, width
        bucket)``, so compiling the full grid up front — every power-of-two
        batch bucket up to ``max_batch`` crossed with the width buckets that
        ``widths`` (default: all configured buckets) fall into, per
        ``n_iters`` value and per backend — guarantees
        measurement/production traffic never stalls on a compile.
        ``backends`` defaults to the engine's default backend only; a
        hybrid deployment that tags requests onto the other backend should
        pass e.g. ``backends=("vdt", "exact")``.  Returns the number of
        executables warmed.  Alpha is a traced argument, so no alpha values
        need covering.  When preemptible dispatch is on, the *resume*
        executable is warmed too — its iteration count is a dynamic loop
        bound, so ONE warm call per shape covers every segment length the
        scheduler can ever slice.
        """
        cbs = sorted(set(bucket_width(int(w), self.buckets)
                         for w in (widths or self.buckets)))
        bbs = []
        b = 1
        while b < self.max_batch:
            bbs.append(b)
            b <<= 1
        bbs.append(self.max_batch)
        count = 0
        caps = self.capabilities()
        for be in (backends or (self.backend,)):
            be = route_backend(be, self.backend, n=self.n)
            if be == "grf" and "grf" not in caps:
                raise ValueError(
                    f"{type(self).__name__} does not serve backend='grf' "
                    f"(capabilities: {sorted(caps)})")
            for ni in n_iters:
                for cb in cbs:
                    for bb in bbs:
                        z = np.zeros((bb, self.n, cb), np.float32)
                        out = self._scan(self.vdt, z,
                                         np.zeros((bb,), np.float32),
                                         int(ni), be)
                        jax.block_until_ready(out)
                        count += 1
                        # grf has no resume executable to warm: it always
                        # dispatches monolithically
                        if (self.segment_iters is not None and be != "grf"
                                and int(ni) > self.segment_iters):
                            out = self._scan_resume(
                                self.vdt, z, z, np.zeros((bb,), np.float32),
                                1, be)
                            jax.block_until_ready(out)
                            count += 1
        return count

    # ------------------------------------------------------------ submission
    def submit(self, request: PropagateRequest, *, block: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Enqueue one request; returns the future of its (N, C) answer.

        Shape/route/recipe problems surface here, not at dispatch —
        :meth:`PropagateRequest.validate
        <repro.serving._batching.PropagateRequest.validate>` pins every
        malformed-request ``ValueError`` (bad shape or width, alpha outside
        ``[0, 1]``, unknown backend tag, non-positive deadline) at the
        submit call site and takes a private copy of the label matrix, so
        the caller may reuse its buffer afterwards.  When the queue is
        full, ``block=True`` waits (up to ``timeout``) for capacity and
        ``block=False`` raises :class:`QueueFull` immediately.  The future
        supports ``cancel()`` any time before its dispatch starts; under
        ``policy="edf"`` it may instead resolve with
        :class:`DeadlineExceeded` if the deadline passes while it is still
        queued.
        """
        if self._closed:
            raise RuntimeError("engine is shut down")
        # pin the serving epoch: validate against the current epoch's shape
        # contract OUTSIDE the lock (validation copies the label matrix),
        # then re-check under the lock that no publish() landed meanwhile —
        # if one did, revalidate against the new epoch's N.  The pending
        # increment happens under the same lock that publishes epochs, so
        # an accepted entry's epoch can never retire before it resolves.
        while True:
            with self._state_lock:
                eid = self._epoch_id
                n = self._epochs[eid].n
            validated = request.validate(n=n, buckets=self.buckets,
                                         default_backend=self.backend)
            if (validated.backend == "grf"
                    and "grf" not in self.capabilities()):
                # capability-gated routing: an engine that cannot serve the
                # walker estimator rejects grf-tagged traffic at the submit
                # call site, like every other malformed-request error
                raise ValueError(
                    f"{type(self).__name__} does not serve backend='grf' "
                    f"(capabilities: {sorted(self.capabilities())})")
            now = self._clock()
            with self._state_lock:
                if self._epoch_id != eid:
                    continue  # publish raced the validation: revalidate
                self._epochs[eid].pending += 1
                seq = self._seq
                self._seq += 1
                # EWMA of inter-arrival gaps -> the adaptive linger's rate
                # estimate; beta 0.25 tracks bursts within ~4 arrivals while
                # smoothing one-off stalls
                if self._last_arrival is not None:
                    gap = max(now - self._last_arrival, 0.0)
                    if self._ewma_gap_s is None:
                        self._ewma_gap_s = gap
                    else:
                        self._ewma_gap_s += 0.25 * (gap - self._ewma_gap_s)
                self._last_arrival = now
            break
        fut: Future = Future()
        entry = QueueEntry(
            seq=seq, request=validated, future=fut, t_submit=now,
            priority=validated.priority,
            t_deadline=None if validated.deadline_ms is None
            else now + validated.deadline_ms / 1e3,
            epoch=eid)
        try:
            self._queue.put(entry, block=block, timeout=timeout)
        except QueueFull:
            with self._state_lock:
                self._epochs[eid].pending -= 1
                self._retire_locked()
            self._metrics.count("rejected")
            raise
        if self._closed and fut.cancel():
            # lost the race with shutdown(): the entry landed after (or
            # during) the final flush, so nobody may ever drain it — cancel
            # rather than hand back a future that could hang forever
            self._metrics.count("cancelled")
            raise RuntimeError("engine is shut down")
        self._metrics.count("submitted")
        return fut

    # ------------------------------------------------------------ scheduling
    def step(self) -> int:
        """One synchronous scheduler iteration: drain + dispatch, no linger.

        Returns the number of futures resolved (results, failures, and
        expired fast-fails).  This is the whole scheduler — the background
        thread calls the same code after its batching wait — so tests drive
        it deterministically.
        """
        self._prune_staging()
        live, cancelled, expired = self._queue.drain(self.max_batch)
        if cancelled:
            self._metrics.count("cancelled", len(cancelled))
            self._release(cancelled)
        resolved = 0
        for entry in expired:
            # edf fast-fail: the deadline passed while queued, so resolve
            # with the pinned exception instead of wasting a dispatch slot
            if entry.future.set_running_or_notify_cancel():
                entry.future.set_exception(DeadlineExceeded(
                    f"deadline_ms={entry.request.deadline_ms} expired "
                    f"before dispatch"))
                self._metrics.count("expired")
                resolved += 1
            else:
                self._metrics.count("cancelled")
        self._release(expired)
        if not live:
            return resolved
        with self._state_lock:
            self._in_flight += len(live)
        try:
            return resolved + self._dispatch(live)
        finally:
            with self._state_lock:
                self._in_flight -= len(live)

    def flush(self) -> int:
        """Drain the backlog *as of this call*; returns futures resolved.

        Deliberately NOT "step until empty": under concurrent producers a
        length-polling loop never terminates as long as arrivals keep pace
        with service (livelock — the flusher, e.g. ``shutdown(wait=True)``,
        would be held hostage by other threads' traffic).  Instead the
        backlog size and the queue's monotone pop counter are snapshotted
        once, and stepping stops as soon as that many entries have been
        popped — everything queued when ``flush`` was called is served,
        while entries racing in afterwards wait for the next scheduler
        pass.
        """
        backlog = len(self._queue)
        if backlog == 0:
            return 0
        start_popped = self._queue.popped
        total = 0
        while (self._queue.popped - start_popped < backlog
               and len(self._queue) > 0):
            total += self.step()
        return total

    # while lingering, arrivals quiescing for this long end the batching
    # window early — resubmit bursts from closed-loop clients land within a
    # few of these, so a lone request never waits out the window even when
    # the rate estimate is stale
    _QUIESCE_S = 1e-3

    def _linger_window_s(self) -> float:
        """Pick this iteration's batching window (seconds).

        Rate-adaptive: the EWMA inter-arrival gap estimates how long the
        remaining ``max_batch - queued`` slots take to fill, and that is
        the window — clamped to ``[0, max_wait_ms]`` (no estimate yet falls
        back to the cap; the quiesce early-exit protects lone requests
        either way).  Under ``policy="edf"`` the window is additionally
        capped at the earliest queued deadline so lingering can never
        itself expire the most urgent request.
        """
        window = cap = self.max_wait_ms / 1e3
        if self.adaptive_linger:
            with self._state_lock:
                gap = self._ewma_gap_s
            if gap is not None:
                missing = max(0, self.max_batch - len(self._queue))
                window = min(cap, gap * missing)
        nearest = self._queue.next_deadline()
        if nearest is not None:
            window = min(window, max(0.0, nearest - self._clock()))
        with self._state_lock:
            # under the lock: metrics() reads this gauge from other threads,
            # and an unsynchronized write can tear the snapshot
            self._linger_window_ms = window * 1e3
        return window

    def _linger(self) -> None:
        """Batching window: wait up to the adaptive window for a fuller
        batch, ending early once the batch is full or arrivals stop."""
        window = self._linger_window_s()
        if window <= 0:
            return
        deadline = self._clock() + window
        seen = len(self._queue)
        while seen < self.max_batch:
            # re-check the most urgent queued deadline every iteration: a
            # tight-deadline request ARRIVING mid-linger must shrink the
            # window, or the linger itself could expire it
            nearest = self._queue.next_deadline()
            if nearest is not None and nearest < deadline:
                deadline = nearest
            remaining = deadline - self._clock()
            if remaining <= 0:
                return
            self._queue.wait_atleast(
                self.max_batch, timeout=min(remaining, self._QUIESCE_S))
            grown = len(self._queue)
            if grown == seen:
                return  # quiesced: dispatch what we have
            seen = grown

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                if not self._queue.wait_nonempty(timeout=0.05):
                    continue
                if self.max_wait_ms > 0:
                    self._linger()
                self.step()
            except Exception:  # never let the scheduler thread die silently
                # per-group errors were already delivered via set_exception;
                # anything reaching here is scheduler-internal.  Count it
                # and log the traceback — a silently swallowed fault looks
                # exactly like a healthy idle engine from the outside —
                # then back off a beat so a persistent fault can't
                # busy-spin the thread
                self._metrics.count("scheduler_errors")
                _log.exception("scheduler iteration failed; backing off")
                self._stop.wait(0.05)

    def _dispatch(self, entries: list[QueueEntry],
                  preemptible: bool = True) -> int:
        """Group, pad, and serve one drained microbatch.

        ``preemptible=False`` forces monolithic scans — the urgent
        service pass dispatches with it so a preemption can never nest
        inside another preemption (unbounded recursion while the original
        suspended walk starves).
        """
        # group by (epoch, n_iters, backend) (+ width bucket unless
        # coalescing) via the canonical serving-tier key: only requests
        # sharing a scan length AND a transition matrix can share a
        # dispatch — and under streaming updates the transition matrix IS
        # the epoch, so entries pinned to different epochs never coalesce
        # (each group dispatches against exactly the model its entries
        # were submitted under, bit-identically).  Backends were resolved
        # at submit, so None / "auto" tags that landed on the same
        # concrete backend coalesce.  Alpha always rides as a traced
        # array and never fragments a group.
        groups: dict[tuple[int, int, str, int], list[QueueEntry]] = {}
        dead: list[QueueEntry] = []
        for entry in entries:
            if not entry.future.set_running_or_notify_cancel():
                self._metrics.count("cancelled")  # cancelled post-drain
                dead.append(entry)
                continue
            key = (entry.epoch,) + dispatch_group_key(
                entry.request, self.buckets,
                coalesce_widths=self.coalesce_widths)
            groups.setdefault(key, []).append(entry)
        self._release(dead)

        resolved = 0
        for (epoch, n_iters, backend, cb), group in sorted(groups.items()):
            with self._state_lock:
                ep = self._epochs[epoch]  # pinned: pending > 0 keeps it live
            vdt, n = ep.vdt, ep.n
            if self.coalesce_widths:
                cb = max(bucket_width(e.request.y0.shape[1], self.buckets)
                         for e in group)
            n_walkers = None
            if backend == "grf":
                # max-over-group walker budget: more walkers strictly
                # tightens every member's estimate, so the hungriest
                # request sets the batch budget (the width-coalescing
                # argument applied to accuracy) — walker count never
                # fragments a group
                n_walkers = max(self._walker_budget(e.request)
                                for e in group)
                with self._state_lock:
                    self._last_n_walkers = n_walkers
            group.sort(key=lambda e: e.seq)  # deterministic batch layout
            urgent_resolved = 0
            try:
                bb = batch_bucket(len(group), self.max_batch)
                stack = self._staging.setdefault(
                    (n, bb, cb), np.zeros((bb, n, cb), np.float32))
                stack.fill(0.0)
                alphas = np.zeros((bb,), np.float32)  # padding rows: alpha 0
                for k, entry in enumerate(group):
                    y0 = entry.request.y0
                    stack[k, :, :y0.shape[1]] = y0
                    alphas[k] = entry.request.alpha
                out, urgent_resolved = self._propagate_group(
                    group, stack, alphas, n_iters, backend, preemptible,
                    vdt, n_walkers=n_walkers)
            except Exception as exc:  # resolve the group, keep scheduling
                for entry in group:
                    entry.future.set_exception(exc)
                self._metrics.count("failed", len(group))
                self._release(group)
                resolved += len(group) + urgent_resolved
                continue
            resolved += urgent_resolved
            self._metrics.record_dispatch(len(group))
            # slot-based result layout (engine_api.ResultSlab): ONE
            # device-to-host copy for the whole group, then each future
            # resolves to a zero-copy view sliced to its true width —
            # host-transfer cost per dispatch is one contiguous array,
            # however many requests coalesced into it
            slab = ResultSlab(
                data=np.asarray(out),
                widths=tuple(e.request.y0.shape[1] for e in group))
            t_done = self._clock()
            for k, entry in enumerate(group):
                entry.future.set_result(slab.view(k))
                self._metrics.record_latency(t_done - entry.t_submit)
                if entry.t_deadline is not None and t_done > entry.t_deadline:
                    # answered, but late: visible in metrics so operators
                    # can tell "meets deadlines" from "merely completes"
                    self._metrics.count("deadline_missed")
            self._metrics.count("completed", len(group))
            self._release(group)
            resolved += len(group)
        return resolved

    # ------------------------------------------------------ epoch lifecycle
    def _release(self, entries) -> None:
        """Drop the epoch pins of terminally-resolved entries; retire drained
        epochs.  Called exactly once per accepted entry, at whichever path
        resolves it (result, failure, cancel, or expiry)."""
        if not entries:
            return
        with self._state_lock:
            for entry in entries:
                ep = self._epochs.get(entry.epoch)
                if ep is not None:
                    ep.pending -= 1
            self._retire_locked()

    def _retire_locked(self) -> None:
        """Drop non-current epochs with no pending entries (lock held).

        Retiring releases the epoch's pinned model (its device dispatch
        buffers go with it once no one else references the tree) and flags
        the staging pool for pruning — buffers sized for a point count no
        live epoch serves are freed by the scheduler thread on its next
        pass (`_prune_staging`), never by whatever submit/publish thread
        happened to drop the last pin.
        """
        dead = [eid for eid, ep in self._epochs.items()
                if eid != self._epoch_id and ep.pending <= 0]
        for eid in dead:
            del self._epochs[eid]
        if dead:
            self._metrics.count("epochs_retired", len(dead))
            self._staging_dirty = True

    def _prune_staging(self) -> None:
        """Free staging buffers no live epoch can use (scheduler thread
        only — the staging pool is single-owner dispatch state)."""
        if not self._staging_dirty:
            return
        with self._state_lock:
            live_n = {ep.n for ep in self._epochs.values()}
            self._staging_dirty = False
        for key in [k for k in self._staging if k[0] not in live_n]:
            del self._staging[key]

    def publish(self, model, *, patched_points: int = 0,
                stale_blocks: int = 0) -> int:
        """Swap in a streaming-updated tree as the next epoch; returns it.

        The epoch-versioned model swap behind online inserts/deletes
        (``core/streaming.py``): ``model`` — typically ``update.vdt`` from
        :func:`~repro.core.streaming.insert_points` /
        :func:`~repro.core.streaming.delete_points` — becomes the current
        epoch atomically with respect to :meth:`submit`.  Entries already
        queued or in flight stay pinned to their submission epoch and
        complete **bit-identically** against that tree (streaming
        mutations are copy-on-write, so the old epoch's arrays are frozen
        by construction); every submit returning after this call validates
        against and dispatches on the new epoch.  Old epochs retire as
        their last entry resolves — their model pin drops and staging
        buffers sized only for them are pruned — and ``metrics()`` tracks
        the swap (``epoch``/``live_epochs`` gauges, ``epochs_published`` /
        ``epochs_retired`` / ``patched_points`` counters).

        ``patched_points`` / ``stale_blocks`` are the streaming update's
        bookkeeping (``StreamUpdate.patched_points`` /
        ``StreamUpdate.stale_blocks``), surfaced as metrics so operators
        can watch model drift and pending refinement debt.  Thread-safe;
        may be called from any thread, any number of times.
        """
        if self._closed:
            raise RuntimeError("engine is shut down")
        n = int(model.tree.n_points)
        divergence = model.divergence_name
        with self._state_lock:
            eid = self._epoch_id + 1
            fp = FitParams(model=model, n_points=n, divergence=divergence,
                           epoch=eid)
            self._epochs[eid] = _Epoch(eid=eid, vdt=model, n=n,
                                       divergence=divergence, fit_params=fp)
            self._epoch_id = eid
            self.vdt = model
            self.n = n
            self.divergence = divergence
            self.dispatch_key = f"{self.backend}:{divergence}"
            self._fit_params = fp
            self._stale_blocks = int(stale_blocks)
            self._retire_locked()
        self._metrics.count("epochs_published")
        if patched_points:
            self._metrics.count("patched_points", int(patched_points))
        return eid

    def _walker_budget(self, request: PropagateRequest) -> int:
        """One grf request's walker budget: explicit > rtol-sized > default."""
        if request.n_walkers is not None:
            return int(request.n_walkers)
        if request.rtol is not None:
            from repro.core.grf import walkers_for_rtol

            return walkers_for_rtol(request.rtol)
        return self.n_walkers

    # ------------------------------------------------------- device dispatch
    # The two scan hooks below are the ONLY places the scheduler touches
    # device math.  Everything above them — queue disciplines, grouping,
    # staging, segmentation, epoch pinning, metrics — is device-layout
    # agnostic, so an engine that runs the same eq.-15 walk on different
    # hardware (the sharded multi-device engine in serving/_sharded.py)
    # overrides exactly these two methods and inherits the whole scheduler.

    def _scan(self, vdt, stack, alphas, n_iters: int, backend: str, *,
              n_walkers=None):
        """One monolithic batched LP dispatch: ``(bb, N, cb)`` in and out.

        ``vdt`` is the pinned epoch's fitted tree (NOT necessarily
        ``self.vdt`` — entries dispatch against the epoch they were
        submitted under).  ``alphas`` is the per-request ``(bb,)`` array
        (padding rows 0); ``n_walkers`` only matters to grf dispatches.
        """
        kw = {}
        if backend == "grf":
            kw = {"n_walkers": int(n_walkers) if n_walkers is not None
                  else self.n_walkers, "seed": self.grf_seed}
        return vdt.label_propagate(stack, alpha=alphas, n_iters=int(n_iters),
                                   batched=True, backend=backend, **kw)

    def _scan_resume(self, vdt, carry, y0, alphas, n_iters, backend: str):
        """``n_iters`` more eq.-15 steps from a mid-walk ``(bb, N, cb)``
        carry — the segmented-dispatch primitive (bit-identical to never
        having paused; ``n_iters`` may be traced)."""
        return vdt.label_propagate_resume(carry, y0, alpha=alphas,
                                          n_iters=n_iters, batched=True,
                                          backend=backend)

    def _propagate_group(self, group: list[QueueEntry], stack: np.ndarray,
                         alphas: np.ndarray, n_iters: int, backend: str,
                         preemptible: bool, vdt=None, n_walkers=None):
        """Run one group's LP walk, segmented and preemptible when enabled.

        Returns ``(out, urgent_resolved)`` where ``out`` is the group's
        final ``(bb, N, cb)`` label stack and ``urgent_resolved`` counts
        futures resolved by urgent service passes taken at segment
        boundaries (0 on the monolithic path).

        The walk is segmented only when it is worth anything: preemption
        enabled (``segment_iters``), the EDF discipline (the only one with
        an urgency signal), the scan actually longer than one segment, and
        an outer (non-nested) dispatch.  Each segment resumes from the
        previous carry via ``label_propagate_resume`` — bit-identical to
        the monolithic scan (eq. 15 is a pure fixed-point iteration; the
        resume primitives take the iteration count as a *dynamic* loop
        bound, so all segment lengths share one compiled executable per
        shape).  After each segment the measured per-iteration device time
        feeds an EWMA, and if anything queued would expire before the
        estimated completion of the remaining iterations, the walk yields
        the device to :meth:`_service_urgent` before resuming.
        """
        if vdt is None:
            vdt = self.vdt
        seg = self.segment_iters
        if backend == "grf":
            # always monolithic: the MC series estimator has no exact
            # resume primitive (label_propagate_resume rejects grf)
            out = self._scan(vdt, stack, alphas, n_iters, "grf",
                             n_walkers=n_walkers)
            jax.block_until_ready(out)
            return out, 0
        # segment only when this configuration actually preempts — the
        # capability the engine itself reports, not an attribute probe
        if (not preemptible or "preempt" not in self.capabilities()
                or int(n_iters) <= seg):
            out = self._scan(vdt, stack, alphas, n_iters, backend)
            jax.block_until_ready(out)
            return out, 0
        # device-resident seed: urgent dispatches between segments refill
        # the SAME staging buffers, so the suspended walk's restart term
        # must not alias the staging pool
        y0_dev = jnp.asarray(stack)
        alphas_dev = jnp.asarray(alphas)
        rec = _InFlightScan(entries=group, carry=y0_dev, y0=y0_dev,
                            alphas=alphas_dev, n_iters=int(n_iters),
                            backend=backend)
        urgent_resolved = 0
        while rec.iters_done < rec.n_iters:
            k = min(seg, rec.n_iters - rec.iters_done)
            t0 = self._clock()
            rec.carry = self._scan_resume(vdt, rec.carry, rec.y0,
                                          rec.alphas, k, rec.backend)
            jax.block_until_ready(rec.carry)
            dt = max(self._clock() - t0, 0.0)
            rec.iters_done += k
            with self._state_lock:
                per_iter = dt / k
                if self._ewma_iter_s is None:
                    self._ewma_iter_s = per_iter
                else:
                    self._ewma_iter_s += 0.25 * (per_iter - self._ewma_iter_s)
                est_iter_s = self._ewma_iter_s
            remaining = rec.n_iters - rec.iters_done
            if remaining <= 0:
                break
            horizon = self._clock() + est_iter_s * remaining
            if self._queue.deadline_before(horizon):
                # segment-boundary yield: an arrival's deadline would
                # expire before the in-flight walk completes — serve it
                # now, then resume from the carry bit-identically
                self._metrics.count("preemptions")
                self._metrics.count("preempt_iters", remaining)
                urgent_resolved += self._service_urgent(horizon)
        return rec.carry, urgent_resolved

    def _service_urgent(self, horizon: float) -> int:
        """Serve queued entries whose deadline falls before ``horizon``.

        The preemption service pass: pops ONLY urgent entries (the EDF
        heap is deadline-ordered, so this is a prefix drain) and
        dispatches them with ``preemptible=False`` — the suspended walk is
        already waiting, and a nested preemption could starve it without
        bound.  Cancelled/expired entries popped on the way resolve
        exactly as in :meth:`step`.
        """
        live, cancelled, expired = self._queue.drain_urgent(
            self.max_batch, horizon)
        if cancelled:
            self._metrics.count("cancelled", len(cancelled))
            self._release(cancelled)
        resolved = 0
        for entry in expired:
            if entry.future.set_running_or_notify_cancel():
                entry.future.set_exception(DeadlineExceeded(
                    f"deadline_ms={entry.request.deadline_ms} expired "
                    f"before dispatch"))
                self._metrics.count("expired")
                resolved += 1
            else:
                self._metrics.count("cancelled")
        self._release(expired)
        if not live:
            return resolved
        with self._state_lock:
            self._in_flight += len(live)
        try:
            return resolved + self._dispatch(live, preemptible=False)
        finally:
            with self._state_lock:
                self._in_flight -= len(live)

    # ----------------------------------------------------------- lifecycle
    def metrics(self) -> MetricsSnapshot:
        with self._state_lock:
            in_flight = self._in_flight
            linger_window_ms = self._linger_window_ms
            epoch = self._epoch_id
            stale_blocks = self._stale_blocks
            live_epochs = len(self._epochs)
            n_walkers = self._last_n_walkers
        return self._metrics.snapshot(
            queue_depth=len(self._queue), in_flight=in_flight,
            dispatch_key=self.dispatch_key, policy=self.policy,
            linger_window_ms=linger_window_ms, epoch=epoch,
            stale_blocks=stale_blocks, live_epochs=live_epochs,
            n_walkers=n_walkers)

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting work; serve (``wait=True``) or cancel the backlog.

        Idempotent.  New ``submit`` calls raise ``RuntimeError`` immediately;
        the background scheduler thread (if any) is joined before the
        backlog is handled, so after return no dispatch is in flight.
        ``wait=False`` cancels every queued *live* future instead of
        serving it (counted under ``cancelled`` in the metrics) — but
        entries whose EDF deadline already expired still resolve with the
        pinned :class:`DeadlineExceeded` (counted under ``expired``):
        "expired" is an outcome the client was promised a typed exception
        for, and a teardown path must not degrade it into a bare cancel.
        Also invoked by the context manager: ``__exit__`` serves the
        backlog on a clean exit and cancels it when unwinding an exception.
        """
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if wait:
            self.flush()
        else:
            live, cancelled, expired = self._queue.drain(self._queue.maxsize)
            n_cancelled = len(cancelled)
            for entry in live:
                entry.future.cancel()
                n_cancelled += 1
            for entry in expired:
                if entry.future.set_running_or_notify_cancel():
                    entry.future.set_exception(DeadlineExceeded(
                        f"deadline_ms={entry.request.deadline_ms} expired "
                        f"before dispatch (engine shut down)"))
                    self._metrics.count("expired")
                else:
                    n_cancelled += 1
            self._metrics.count("cancelled", n_cancelled)
            self._release(live + cancelled + expired)
