"""Once-per-process deprecation warnings for the legacy serving shims.

Every deprecated deep module (``repro.serving.engine``, ``.queue``,
``.metrics``, ``.propagate``, ``.decode``) funnels its import-time warning
through :func:`warn_once` so a process that imports several shims — or
re-imports one via different paths — sees exactly ONE warning per module,
not one per import site.  Python's module cache already makes a plain
module-level ``warnings.warn`` fire once per process, but only as long as
the module stays cached; test harnesses that purge ``sys.modules`` (or
``importlib.reload``) would re-fire it.  Centralizing the ledger here also
gives tests a deterministic reset point: clear ``_WARNED`` and the next
import warns again.

The blessed surface (``import repro.serving``) never calls this module —
the warning-free property of the public path is pinned by
``tests/test_api_surface.py``.
"""
from __future__ import annotations

import warnings

__all__ = ["warn_once"]

# module names that have already warned this process (tests clear this)
_WARNED: set[str] = set()


def warn_once(module: str, replacement: str) -> None:
    """Emit ``module``'s DeprecationWarning once per process.

    ``stacklevel=3`` skips this helper and the shim's module body so the
    warning points at the importer's frame, same as the historical
    module-level ``warnings.warn(..., stacklevel=2)`` did.
    """
    if module in _WARNED:
        return
    _WARNED.add(module)
    warnings.warn(
        f"{module} is deprecated; {replacement}",
        DeprecationWarning, stacklevel=3)
