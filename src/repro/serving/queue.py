"""Deprecated shim: import from :mod:`repro.serving` instead.

The queue implementation moved to the private ``repro.serving._queue``
module; this module re-exports the historical names so existing imports
keep working, with a once-per-process :class:`DeprecationWarning` at
import time.  The public exceptions (``QueueFull``, ``DeadlineExceeded``) are re-exported
from :mod:`repro.serving`; the queue machinery itself (``RequestQueue``,
``QueueEntry``, ``DISCIPLINES``) is engine-internal.
"""
from repro.serving._deprecation import warn_once
from repro.serving._queue import (DEFAULT_AGING_S, DISCIPLINES,
                                  DeadlineExceeded, QueueEntry, QueueFull,
                                  RequestQueue)

warn_once(
    "repro.serving.queue",
    "import QueueFull and DeadlineExceeded from repro.serving (queue "
    "internals live in repro.serving._queue)")

__all__ = ["DEFAULT_AGING_S", "DISCIPLINES", "DeadlineExceeded", "QueueEntry",
           "QueueFull", "RequestQueue"]
