"""Bounded request queue for the continuous-batching engine.

A thin condition-variable wrapper around a deque, purpose-built for the
scheduler's access pattern:

* producers (``PropagateEngine.submit``) ``put`` one entry, either failing
  fast (``QueueFull``) or blocking until space frees — the engine's
  backpressure;
* the single scheduler consumer waits for the queue to go non-empty
  (``wait_nonempty``) and then ``drain``\\ s up to a whole microbatch in one
  lock acquisition, skipping entries whose future was already cancelled.

``stdlib queue.Queue`` fits none of this: no multi-item atomic drain, no
cancellation filtering, and its unfinished-task accounting is dead weight
here.

Concurrency contract
--------------------
All methods are thread-safe; any number of producer threads may ``put``
concurrently.  The design assumes a SINGLE consumer (the engine's
scheduler): ``wait_nonempty``/``wait_atleast`` + ``drain`` are only
race-free in the sense that one consumer sees every entry exactly once —
two concurrent drainers would simply split the backlog between them.
Cancellation is cooperative: cancelling an entry's future while it is
queued guarantees it never reaches a dispatch (the next ``drain`` discards
it), but cancellation after a drain has returned the entry is the
dispatcher's problem (see ``PropagateEngine._dispatch``).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from concurrent.futures import Future
from typing import Optional

__all__ = ["QueueFull", "QueueEntry", "RequestQueue"]


class QueueFull(RuntimeError):
    """Raised by a non-blocking ``put`` when the queue is at capacity."""


@dataclasses.dataclass
class QueueEntry:
    """A submitted request riding through the scheduler."""

    seq: int  # submission order, for deterministic tie-breaks
    request: object  # PropagateRequest
    future: Future  # resolved by the dispatch that serves it
    t_submit: float  # perf_counter at accept, for latency metrics


class RequestQueue:
    """Bounded FIFO with atomic multi-item drain and cancel filtering."""

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._items: deque[QueueEntry] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def put(self, entry: QueueEntry, block: bool = True, timeout: Optional[float] = None) -> None:
        """Append ``entry``; raise :class:`QueueFull` if no space appears.

        ``block=False`` fails fast at capacity; ``block=True`` waits until a
        drain frees space, up to ``timeout`` seconds (``None`` = forever).
        This is the engine's backpressure surface: a saturated engine makes
        producers either slow down (blocking) or shed load (QueueFull).
        """
        with self._not_full:
            if len(self._items) >= self.maxsize:
                if not block:
                    raise QueueFull(f"queue at capacity ({self.maxsize}); retry or raise max_queue")
                has_room = lambda: len(self._items) < self.maxsize  # noqa: E731
                if not self._not_full.wait_for(has_room, timeout=timeout):
                    raise QueueFull(f"queue still full after {timeout}s; engine saturated")
            self._items.append(entry)
            self._not_empty.notify()

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Block until at least one entry is queued (or timeout); True if so."""
        with self._not_empty:
            return self._not_empty.wait_for(lambda: bool(self._items), timeout=timeout)

    def wait_atleast(self, n: int, timeout: Optional[float] = None) -> bool:
        """Block until ``>= n`` entries are queued (or timeout); True if so.

        The scheduler's batching window: after the first request of an
        iteration lands, linger briefly for the batch to fill before
        dispatching a partial one.
        """
        with self._not_empty:
            return self._not_empty.wait_for(lambda: len(self._items) >= n, timeout=timeout)

    def drain(self, max_items: int) -> tuple[list[QueueEntry], list[QueueEntry]]:
        """Atomically pop up to ``max_items`` live entries (FIFO order).

        Returns ``(live, cancelled)``: entries whose future was cancelled
        while queued never reach a dispatch, but still free queue capacity
        (and don't count against ``max_items``).
        """
        live: list[QueueEntry] = []
        cancelled: list[QueueEntry] = []
        with self._not_full:
            while self._items and len(live) < max_items:
                entry = self._items.popleft()
                if entry.future.cancelled():
                    cancelled.append(entry)
                    continue
                live.append(entry)
            if live or cancelled:
                self._not_full.notify_all()
        return live, cancelled
