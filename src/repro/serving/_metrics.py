"""Engine observability: thread-safe counters + latency quantiles.

The engine records one event per lifecycle transition (submit, reject,
cancel, expire, dispatch, complete); :meth:`EngineMetrics.snapshot` folds
them into an immutable :class:`MetricsSnapshot` that benchmarks and
operators read.  Latencies live in a bounded ring (newest
:data:`LATENCY_WINDOW` samples), so a long-running engine reports *recent*
p50/p95 rather than lifetime ones and memory stays O(1).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque

__all__ = ["EngineMetrics", "MetricsSnapshot", "LATENCY_WINDOW"]

# newest-K latency ring: big enough for stable p95, small enough to be O(1)
LATENCY_WINDOW = 4096


def _quantile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Point-in-time view of engine health (all times milliseconds).

    Counter fields are monotone lifetime totals; gauge fields
    (``queue_depth``, ``in_flight``, ``linger_window_ms``) are
    instantaneous; latency quantiles cover the newest
    :data:`LATENCY_WINDOW` completed requests, measured from queue accept
    (``submit`` return) to future resolution — i.e. they include
    queueing/linger time, not just device time.  Conservation: every
    accepted request ends in exactly one of ``completed``, ``failed``,
    ``cancelled`` or ``expired`` (``submitted`` minus those four = queued
    or in flight); ``rejected`` requests were never accepted and appear in
    no other counter.  ``deadline_missed`` is an annotation on
    ``completed``: answers that resolved successfully but after their
    request's deadline (only the ``edf`` discipline fast-fails instead).
    """

    dispatch_key: str = ""  # engine identity: "backend:divergence" — two
    #   engines sharing a process but differing in backend or fitted
    #   divergence report different keys, mirroring the fact that their
    #   dispatches can never share (or cross-contaminate) a compiled
    #   executable.  A hybrid engine (per-request backends) reports its
    #   DEFAULT backend here; per-group backends ride the dispatch itself.
    policy: str = ""  # queue discipline: "fifo" | "priority" | "edf"
    submitted: int = 0  # accepted into the queue (excludes rejected)
    rejected: int = 0  # refused at submit: queue at capacity (backpressure)
    cancelled: int = 0  # future.cancel() won before the dispatch started
    expired: int = 0  # edf fast-fail: deadline passed while queued
    deadline_missed: int = 0  # completed, but later than the deadline
    completed: int = 0  # futures resolved with a result
    failed: int = 0  # futures resolved with an exception (bad dispatch)
    dispatches: int = 0  # batched device dispatches issued
    batched_requests: int = 0  # real (non-padding) requests in those dispatches
    scheduler_errors: int = 0  # scheduler-internal faults the loop survived
    #   (NOT per-request failures — those resolve futures and count under
    #   ``failed``); nonzero here means the background thread hit and
    #   logged an unexpected exception, so check the logs
    preemptions: int = 0  # segment-boundary yields: an in-flight segmented
    #   scan paused so urgent-deadline arrivals could dispatch first
    preempt_iters: int = 0  # LP iterations still pending at those yields —
    #   the amount of in-flight work each preemption stepped in front of
    epochs_published: int = 0  # streaming model swaps accepted (publish())
    epochs_retired: int = 0  # old epochs fully drained and dropped — their
    #   pinned FitParams and any staging buffers sized for them released
    patched_points: int = 0  # points inserted/deleted across all publishes
    epoch: int = 0  # current serving epoch (gauge; 0 = the fitted model)
    stale_blocks: int = 0  # blocks awaiting refinement priority on the
    #   current epoch, as reported by the last publish (gauge)
    live_epochs: int = 1  # epochs still pinned by queued/in-flight entries,
    #   including the current one (gauge; >1 means an old epoch is still
    #   draining)
    n_walkers: int = 0  # walker budget of the most recent grf dispatch
    #   (gauge; 0 = no grf group dispatched yet).  A grf group dispatches
    #   at the MAX budget over its members, so this is the budget actual
    #   device work ran at — the accuracy-vs-latency dial operators watch
    queue_depth: int = 0  # entries waiting right now (gauge)
    in_flight: int = 0  # drained but not yet resolved (gauge)
    linger_window_ms: float = float("nan")  # current adaptive batching window
    latency_p50_ms: float = float("nan")  # windowed submit->result median
    latency_p95_ms: float = float("nan")  # windowed tail latency
    latency_mean_ms: float = float("nan")  # windowed mean

    @property
    def batch_occupancy(self) -> float:
        """Mean real requests per dispatch (the continuous-batching win)."""
        if self.dispatches == 0:
            return float("nan")
        return self.batched_requests / self.dispatches


class EngineMetrics:
    """Mutable, lock-guarded event sink behind :class:`MetricsSnapshot`."""

    def __init__(self, latency_window: int = LATENCY_WINDOW):
        self._lock = threading.Lock()
        self._counts = dict(
            submitted=0,
            rejected=0,
            cancelled=0,
            expired=0,
            deadline_missed=0,
            completed=0,
            failed=0,
            dispatches=0,
            batched_requests=0,
            scheduler_errors=0,
            preemptions=0,
            preempt_iters=0,
            epochs_published=0,
            epochs_retired=0,
            patched_points=0,
        )
        self._latencies_ms: deque[float] = deque(maxlen=latency_window)

    def count(self, event: str, n: int = 1) -> None:
        with self._lock:
            self._counts[event] += n

    def record_dispatch(self, n_requests: int) -> None:
        with self._lock:
            self._counts["dispatches"] += 1
            self._counts["batched_requests"] += n_requests

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            self._latencies_ms.append(seconds * 1e3)

    def snapshot(
        self,
        queue_depth: int = 0,
        in_flight: int = 0,
        dispatch_key: str = "",
        policy: str = "",
        linger_window_ms: float = float("nan"),
        epoch: int = 0,
        stale_blocks: int = 0,
        live_epochs: int = 1,
        n_walkers: int = 0,
    ) -> MetricsSnapshot:
        with self._lock:
            lat = sorted(self._latencies_ms)
            counts = dict(self._counts)
        mean = sum(lat) / len(lat) if lat else float("nan")
        return MetricsSnapshot(
            dispatch_key=dispatch_key,
            policy=policy,
            queue_depth=queue_depth,
            in_flight=in_flight,
            linger_window_ms=linger_window_ms,
            epoch=epoch,
            stale_blocks=stale_blocks,
            live_epochs=live_epochs,
            n_walkers=n_walkers,
            latency_p50_ms=_quantile(lat, 0.50),
            latency_p95_ms=_quantile(lat, 0.95),
            latency_mean_ms=mean,
            **counts,
        )
