"""Serving: prefill (build KV/SSM caches from context) and single-token
decode steps for every architecture family.

Cache layouts (all stacked over layers for scan):
  dense/moe/vlm : KVCache (L, B, W, Hkv, Dh); W = full context, or a
                  sliding-window ring buffer for pure-SWA archs (mixtral).
  ssm           : SSMCache (L, ...) — O(1) state per layer, any context len.
  hybrid        : SSMCache (L, ...) + KVCache (n_attn_points, ...) for the
                  shared attention block applications.
  audio (enc-dec): decoder self-attn KVCache (L, ...) + precomputed
                  cross-attention K/V from the encoder output.

Deprecated as a serving entry point: the label-propagation names it
re-exports (``PropagateEngine``, ``PropagateRequest``, ...) moved to the
blessed :mod:`repro.serving` surface; importing this module emits a
once-per-process :class:`DeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_act
from repro.models.attention import KVCache, attn_apply, attn_decode, init_cache
from repro.models.layers import Dtypes, mlp_apply, rms_norm, rope
from repro.models.moe import moe_apply
from repro.models.ssm import SSMCache, init_ssm_cache, ssm_apply, ssm_decode
from repro.models.transformer import HUGE_WINDOW, layer_windows
from repro.models.whisper import encoder_forward
# Label-propagation requests ride the same serving layer: propagate_many
# pads/buckets variable-width label matrices into batched VDT dispatches,
# and PropagateEngine serves a live queue of them with continuous batching.
from repro.serving._batching import PropagateRequest
from repro.serving._deprecation import warn_once
from repro.serving._engine import PropagateEngine
from repro.serving._metrics import MetricsSnapshot
from repro.serving._propagate import propagate_many
from repro.serving._queue import DeadlineExceeded, QueueFull

warn_once(
    "repro.serving.decode",
    "import the serving names (PropagateEngine, PropagateRequest, "
    "propagate_many, ...) from repro.serving")

__all__ = ["DecodeState", "init_state", "prefill", "decode_step",
           "DECODE_SLACK", "DeadlineExceeded", "MetricsSnapshot",
           "PropagateEngine", "PropagateRequest", "QueueFull",
           "propagate_many"]

# non-ring caches reserve this many slots beyond the prefilled context
DECODE_SLACK = 16


def _finalize_kv(ks, vs, s: int, ring: bool, window: int | None):
    """Lay out prefilled K/V for decoding.

    ring:  keep the last ``window`` tokens, *rolled* so token t sits at slot
           t %% window (what attn_decode's ring indexing expects).
    else:  pad ``DECODE_SLACK`` empty slots for upcoming tokens.
    """
    if ring:
        w = min(s, window)
        ks, vs = ks[:, :, -w:], vs[:, :, -w:]
        shift = s % w
        if shift:
            ks = jnp.roll(ks, shift, axis=2)
            vs = jnp.roll(vs, shift, axis=2)
        return ks, vs
    pad = [(0, 0), (0, 0), (0, DECODE_SLACK), (0, 0), (0, 0)]
    return jnp.pad(ks, pad), jnp.pad(vs, pad)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DecodeState:
    kv: Optional[KVCache] = None        # stacked over layers
    ssm: Optional[SSMCache] = None      # stacked over layers
    shared_kv: Optional[KVCache] = None  # hybrid: stacked over attn points
    cross_k: Optional[jax.Array] = None  # (L, B, Tenc, Hkv, Dh)
    cross_v: Optional[jax.Array] = None


def _stack(items):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *items)


def _n_attn_points(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.layer_is_attn(i))


def init_state(cfg, batch: int, max_len: int) -> DecodeState:
    dt = Dtypes.compute(cfg)
    fam = cfg.family
    if fam == "ssm":
        return DecodeState(
            ssm=_stack([init_ssm_cache(cfg, batch, dt)] * cfg.n_layers))
    if fam == "hybrid":
        n_attn = _n_attn_points(cfg)
        # long contexts use the sliding window for the shared block (SWA)
        return DecodeState(
            ssm=_stack([init_ssm_cache(cfg, batch, dt)] * cfg.n_layers),
            shared_kv=_stack([init_cache(cfg, batch, max_len, dt)] * n_attn),
        )
    if fam == "audio":
        b = batch
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        return DecodeState(
            kv=_stack([init_cache(cfg, batch, max_len, dt)] * cfg.n_layers),
            cross_k=jnp.zeros((cfg.n_layers, b, cfg.encoder_frames, hkv, hd), dt),
            cross_v=jnp.zeros((cfg.n_layers, b, cfg.encoder_frames, hkv, hd), dt),
        )
    return DecodeState(
        kv=_stack([init_cache(cfg, batch, max_len, dt)] * cfg.n_layers))


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def prefill(params, tokens: jax.Array, cfg,
            patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None):
    """Run the context through the model, building caches.

    Returns (last-position logits (B, Vp), DecodeState).
    """
    dt = Dtypes.compute(cfg)
    fam = cfg.family

    if fam == "audio":
        return _prefill_audio(params, tokens, frames, cfg, dt)

    x = params["embed"][tokens].astype(dt)
    if patches is not None:
        x = jnp.concatenate([patches.astype(dt), x], axis=1)
    x = shard_act(x, "btd")
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    windows = layer_windows(cfg)

    if fam in ("ssm", "hybrid"):
        return _prefill_ssm(params, x, pos, cfg, dt)

    def body(x, scanned):
        lp, w = scanned
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        # attention that also emits this layer's K/V for the cache
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_
        k = (h @ lp["attn"]["w_k"].astype(dt)).reshape(b, s, hkv, hd)
        v = (h @ lp["attn"]["w_v"].astype(dt)).reshape(b, s, hkv, hd)
        _, k = rope(k, k, pos, cfg.rope_theta)  # rope on k only
        a = attn_apply(lp["attn"], h, cfg, pos, window=w)
        x = x + shard_act(a, "btd")
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            m, _ = moe_apply(lp["moe"], h2, cfg, dt)
        else:
            m = mlp_apply(lp["mlp"], h2, dt)
        x = x + shard_act(m, "btd")
        return x, (k, v)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows),
                               unroll=cfg.scan_unroll or 1)

    ring = cfg.sliding_window is not None and cfg.local_global_ratio == 0
    ks, vs = _finalize_kv(ks, vs, s, ring, cfg.sliding_window)
    state = DecodeState(kv=KVCache(
        k=ks, v=vs, pos=jnp.full((cfg.n_layers,), s, jnp.int32), ring=ring))

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x[:, -1] @ unemb.astype(dt))
    return logits, state


def _prefill_ssm(params, x, pos, cfg, dt):
    b = x.shape[0]
    shared = params.get("shared_attn")
    n_attn = _n_attn_points(cfg)

    shared_ks, shared_vs = [], []

    def run(x):
        caches = []
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            out, hf = ssm_apply(lp["ssm"], h, cfg, dt, return_state=True)
            x = x + shard_act(out, "btd")
            # conv cache: last K-1 pre-conv channel inputs
            proj = h @ lp["ssm"]["in_proj"].astype(dt)
            di = cfg.d_inner
            gn = cfg.ssm_groups * cfg.ssm_state
            xbc = proj[..., di : di + di + 2 * gn]
            caches.append(SSMCache(conv=xbc[:, -(cfg.ssm_conv - 1):], state=hf))
            if cfg.family == "hybrid" and cfg.layer_is_attn(i):
                sh = rms_norm(x, shared["ln1"], cfg.norm_eps)
                hkv, hd = cfg.n_kv_heads, cfg.head_dim_
                s = x.shape[1]
                k = (sh @ shared["attn"]["w_k"].astype(dt)).reshape(b, s, hkv, hd)
                v = (sh @ shared["attn"]["w_v"].astype(dt)).reshape(b, s, hkv, hd)
                _, k = rope(k, k, pos, cfg.rope_theta)
                kvs.append((k, v))
                w = jnp.int32(cfg.sliding_window or HUGE_WINDOW)
                a = attn_apply(shared["attn"], sh, cfg, pos, window=w)
                x2 = x + shard_act(a, "btd")
                m = mlp_apply(shared["mlp"],
                              rms_norm(x2, shared["ln2"], cfg.norm_eps), dt)
                x = x2 + shard_act(m, "btd")
        return x, caches, kvs

    x, caches, kvs = run(x)
    s = x.shape[1]
    state_kw = dict(ssm=_stack(caches))
    if cfg.family == "hybrid" and n_attn:
        ks = jnp.stack([k for k, _ in kvs])
        vs = jnp.stack([v for _, v in kvs])
        ring = cfg.sliding_window is not None
        ks, vs = _finalize_kv(ks, vs, s, ring, cfg.sliding_window)
        state_kw["shared_kv"] = KVCache(
            k=ks, v=vs, pos=jnp.full((n_attn,), s, jnp.int32), ring=ring)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return x[:, -1] @ unemb.astype(dt), DecodeState(**state_kw)


def _prefill_audio(params, tokens, frames, cfg, dt):
    enc = encoder_forward(params, frames, cfg)
    b, s = tokens.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim_
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        k = (h @ lp["attn"]["w_k"].astype(dt)).reshape(b, s, hkv, hd)
        v = (h @ lp["attn"]["w_v"].astype(dt)).reshape(b, s, hkv, hd)
        _, k = rope(k, k, pos, cfg.rope_theta)
        a = attn_apply(lp["attn"], h, cfg, pos)
        x = x + a
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        ck = (enc @ lp["xattn"]["w_k"].astype(dt)).reshape(
            b, enc.shape[1], hkv, hd)
        cv = (enc @ lp["xattn"]["w_v"].astype(dt)).reshape(
            b, enc.shape[1], hkv, hd)
        c = attn_apply(lp["xattn"], hx, cfg, pos, kv_x=enc, use_rope=False)
        x = x + c
        m = mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), dt)
        return x + m, (k, v, ck, cv)

    x = params["embed"][tokens].astype(dt)
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, (ks, vs, cks, cvs) = jax.lax.scan(
        body, x, params["layers"], unroll=cfg.scan_unroll or 1)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = x[:, -1] @ params["unembed"].astype(dt)
    ks, vs = _finalize_kv(ks, vs, s, False, None)
    state = DecodeState(
        kv=KVCache(k=ks, v=vs, pos=jnp.full((cfg.n_layers,), s, jnp.int32),
                   ring=False),
        cross_k=cks, cross_v=cvs,
    )
    return logits, state


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode_step(params, token: jax.Array, state: DecodeState, cfg):
    """token: (B, 1) -> (logits (B, Vp), new DecodeState)."""
    dt = Dtypes.compute(cfg)
    fam = cfg.family
    x = params["embed"][token].astype(dt)  # (B, 1, D)

    if fam in ("ssm", "hybrid"):
        x, new_state = _decode_ssm(params, x, state, cfg, dt)
    elif fam == "audio":
        x, new_state = _decode_audio(params, x, state, cfg, dt)
    else:
        x, new_state = _decode_attn(params, x, state, cfg, dt)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unemb = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return (x[:, 0] @ unemb.astype(dt)), new_state


def _decode_attn(params, x, state, cfg, dt):
    windows = layer_windows(cfg)

    def body(x, scanned):
        lp, cache, w = scanned
        a, new_cache = attn_decode(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cache, cfg,
            window=w)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            m, _ = moe_apply(lp["moe"], h, cfg, dt)
        else:
            m = mlp_apply(lp["mlp"], h, dt)
        return x + m, new_cache

    x, new_kv = jax.lax.scan(body, x, (params["layers"], state.kv, windows),
                             unroll=cfg.scan_unroll or 1)
    return x, DecodeState(kv=new_kv, cross_k=state.cross_k,
                          cross_v=state.cross_v)


def _decode_ssm(params, x, state, cfg, dt):
    shared = params.get("shared_attn")
    new_ssm, new_shared = [], []
    attn_pt = 0
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        cache = jax.tree_util.tree_map(lambda a: a[i], state.ssm)
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        out, c2 = ssm_decode(lp["ssm"], h, cache, cfg, dt)
        x = x + out
        new_ssm.append(c2)
        if cfg.family == "hybrid" and cfg.layer_is_attn(i):
            kv = jax.tree_util.tree_map(lambda a: a[attn_pt], state.shared_kv)
            kv = KVCache(k=kv.k, v=kv.v, pos=kv.pos, ring=state.shared_kv.ring)
            a, kv2 = attn_decode(
                shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps), kv,
                cfg, window=jnp.int32(cfg.sliding_window or HUGE_WINDOW))
            x2 = x + a
            m = mlp_apply(shared["mlp"],
                          rms_norm(x2, shared["ln2"], cfg.norm_eps), dt)
            x = x2 + m
            new_shared.append(kv2)
            attn_pt += 1
    new_state = DecodeState(
        ssm=_stack(new_ssm),
        shared_kv=_stack(new_shared) if new_shared else None,
    )
    return x, new_state


def _decode_audio(params, x, state, cfg, dt):
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    b = x.shape[0]

    def body(x, scanned):
        lp, cache, ck, cv = scanned
        a, new_cache = attn_decode(
            lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cache, cfg)
        x = x + a
        # cross attention against precomputed encoder K/V
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = (h @ lp["xattn"]["w_q"].astype(dt)).reshape(b, 1, hq, hd)
        rep = hq // hkv
        kk = jnp.repeat(ck, rep, axis=2)
        vv = jnp.repeat(cv, rep, axis=2)
        lg = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / (hd ** 0.5)
        p = jax.nn.softmax(lg.astype(jnp.float32), -1).astype(dt)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vv).reshape(b, 1, hq * hd)
        x = x + o @ lp["xattn"]["w_o"].astype(dt)
        m = mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps), dt)
        return x + m, new_cache

    x, new_kv = jax.lax.scan(
        body, x, (params["layers"], state.kv, state.cross_k, state.cross_v),
        unroll=cfg.scan_unroll or 1)
    return x, DecodeState(kv=new_kv, cross_k=state.cross_k,
                          cross_v=state.cross_v)
