"""Multi-tenant engine fleet: one process, many fitted trees, fair shares.

The paper's premise is that one fitted variational dual tree amortizes
across arbitrarily many random-walk queries; a production process takes the
next step and serves *many* fitted trees — one per dataset/graph/customer —
from a single scheduler.  :class:`EngineFleet` is that front-end:

* **Registration** (``register``): ``tenant name -> fitted tree -> engine``.
  Each tenant gets its own :class:`~repro.serving.PropagateEngine`
  (``start=False`` — the fleet owns the only scheduler) over its tree plus
  a fair-queueing ``weight``.  Several tenants may share one fitted tree
  (same graph, different traffic classes): ``fit_params`` is immutable, so
  sharing is free.
* **Routing** (``submit``): each request routes by its
  ``PropagateRequest.tenant`` tag to that tenant's engine — *above* the
  engines, so within a tenant the scheduler-v2 dispatch group key
  ``(n_iters, backend)`` applies unchanged and tenancy never fragments an
  otherwise-coalescible batch.  Per-tenant bounded queues mean one
  tenant's backpressure (``QueueFull``) never steals another tenant's
  capacity, and per-tenant futures/queues make cross-tenant interference
  structurally impossible: nothing the fleet does to tenant A's entries
  (cancel, expire, fail) can ever resolve a future belonging to tenant B.
* **Fair queueing** (``step_round`` / the background thread): weighted
  **deficit round robin** across the per-tenant queues.  Every round, each
  backlogged tenant's deficit grows by ``quantum * weight`` and the tenant
  dispatches microbatches (plain ``engine.step()`` calls) while its
  deficit covers their cost (one unit per request served):

      deficit_t += quantum * weight_t          # each round, if backlogged
      while deficit_t >= 1 and backlog_t:      # serve, paying per request
          deficit_t -= engine_t.step()

  A microbatch larger than the remaining deficit still dispatches whole
  (batching is the whole point) and drives the deficit negative — debt the
  tenant repays over later rounds, so *long-run* throughput shares converge
  to the weights even though individual dispatches are coarse.  Like the
  ``"priority"`` discipline's aging, the policy is **starvation-bounded**:
  a backlogged tenant's deficit grows every round regardless of the other
  tenants, so it dispatches at least once every
  ``ceil(max_batch / (quantum * weight))`` rounds — no weight is small
  enough to be starved outright.  An emptied tenant's deficit resets to
  zero (classic DRR), so idle time banks no credit.

Single-tenant parity: a fleet with one registered tenant adds routing and
a trivial DRR loop around exactly the same engine code path — dispatch
composition, padding, kernels, and results are bit-identical to driving a
bare ``PropagateEngine`` (pinned by ``tests/test_fleet.py``).
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Mapping, Optional

from repro.serving._batching import PropagateRequest
from repro.serving._engine import PropagateEngine
from repro.serving._metrics import MetricsSnapshot

__all__ = ["EngineFleet", "FleetMetricsSnapshot"]


@dataclasses.dataclass
class _Tenant:
    """One registered tenant: its engine, weight, and DRR accounting."""

    name: str
    engine: PropagateEngine
    weight: float
    deficit: float = 0.0  # DRR credit (may go negative: microbatch debt)
    served: int = 0  # lifetime requests resolved by fleet-driven dispatches


@dataclasses.dataclass(frozen=True)
class FleetMetricsSnapshot:
    """Point-in-time view of fleet health, tenant-keyed and deep-copied.

    Every mapping on this snapshot is freshly built (deep-copied) at
    snapshot time: mutating a snapshot can never corrupt the live
    scheduler's accounting, and two snapshots never alias each other —
    the namespacing contract ``tests/test_fleet.py`` pins.

    ``fair_share_err`` is the worst relative deviation of any tenant's
    measured lifetime throughput share from its weight share,
    ``max_t |served_t / total - weight_t / sum(weights)| / (weight_t /
    sum(weights))`` — 0.0 is perfect weighted fairness; NaN until at least
    two tenants have been served.  Lifetime counters only converge to the
    weights under sustained all-tenants-backlogged load; windowed
    measurements (e.g. the ``multi-tenant`` benchmark scenario) should
    difference two snapshots instead.
    """

    tenants: Mapping[str, MetricsSnapshot]  # per-tenant engine snapshots
    weights: Mapping[str, float]  # configured fair-queueing weights
    served: Mapping[str, int]  # per-tenant requests resolved by the fleet
    rounds: int  # DRR rounds executed
    fair_share_err: float  # worst relative share deviation (see above)


def _fair_share_err(served: Mapping[str, int],
                    weights: Mapping[str, float]) -> float:
    total = sum(served.values())
    active = {t: w for t, w in weights.items() if w > 0}
    if total == 0 or len(active) < 2:
        return float("nan")
    wsum = sum(active.values())
    worst = 0.0
    for t, w in active.items():
        expected = w / wsum
        measured = served.get(t, 0) / total
        worst = max(worst, abs(measured - expected) / expected)
    return worst


class EngineFleet:
    """Multi-tenant serving front-end over per-tenant engines (see module
    docstring for the routing and fair-queueing semantics).

    Parameters
    ----------
    quantum:  DRR credit added per round per unit weight (requests).  The
              default of 8 lets a weight-1 tenant clear a typical
              microbatch every round or two while keeping per-round work
              bounded; fairness converges to the weights for any positive
              value, the quantum only sets how coarsely.
    clock:    monotonic time source handed to every registered engine (so
              one fake clock drives the whole fleet deterministically
              under test).
    start:    spawn the fleet scheduler thread.  ``start=False`` leaves
              scheduling to explicit ``step_round``/``flush`` calls — the
              deterministic mode the unit tests and golden parity checks
              drive.
    """

    def __init__(self, *, quantum: float = 8.0,
                 clock: Callable[[], float] = time.perf_counter,
                 start: bool = True):
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = float(quantum)
        self._clock = clock
        self._tenants: dict[str, _Tenant] = {}
        self._rounds = 0
        self._lock = threading.Lock()
        self._work = threading.Event()  # set on submit: wake the scheduler
        self._stop = threading.Event()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if start:
            self._thread = threading.Thread(
                target=self._loop, name="engine-fleet", daemon=True)
            self._thread.start()

    # ---------------------------------------------------------- registration
    def register(self, tenant: str, vdt, *, weight: float = 1.0,
                 engine_cls: type = PropagateEngine,
                 **engine_kwargs) -> PropagateEngine:
        """Register ``tenant`` served by a new engine over ``vdt``.

        ``weight`` is the tenant's fair share (relative to the other
        tenants' weights).  ``engine_cls`` picks the engine implementation
        (default :class:`~repro.serving.PropagateEngine`; pass
        :class:`~repro.serving.ShardedPropagateEngine` to serve this
        tenant SPMD across the device mesh — routing, fair queueing, and
        the dispatch group key are engine-agnostic, so mixing sharded and
        single-device tenants in one fleet needs nothing else).
        ``engine_kwargs`` pass through to the engine constructor
        (``max_batch``, ``policy``, ``segment_iters``, ...) except
        ``start``/``clock``, which the fleet pins: the fleet owns the ONLY
        scheduler, so tenant engines never spawn their own threads, and
        all timing runs on the fleet clock.  Returns the tenant's engine
        (mainly so callers can ``warmup`` it).
        """
        if weight <= 0:
            raise ValueError(
                f"tenant weight must be > 0, got {weight} for {tenant!r}")
        for pinned in ("start", "clock"):
            if pinned in engine_kwargs:
                raise ValueError(
                    f"{pinned!r} is fleet-managed and cannot be passed "
                    f"per tenant")
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is shut down")
            if tenant in self._tenants:
                raise ValueError(f"tenant {tenant!r} already registered")
        # engine construction compiles nothing but does touch the fitted
        # tree; keep it outside the lock so a slow register never blocks
        # the scheduler's tenant-list snapshot
        engine = engine_cls(vdt, start=False, clock=self._clock,
                            **engine_kwargs)
        with self._lock:
            if self._closed:  # lost a race with shutdown()
                engine.shutdown(wait=False)
                raise RuntimeError("fleet is shut down")
            if tenant in self._tenants:
                engine.shutdown(wait=False)
                raise ValueError(f"tenant {tenant!r} already registered")
            self._tenants[tenant] = _Tenant(
                name=tenant, engine=engine, weight=float(weight))
        return engine

    def tenants(self) -> tuple[str, ...]:
        """Registered tenant names, in registration (round-robin) order."""
        with self._lock:
            return tuple(self._tenants)

    # -------------------------------------------------------------- routing
    def submit(self, request: PropagateRequest, *, block: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Route ``request`` to its tenant's engine; returns that future.

        ``request.tenant`` must name a registered tenant; ``None`` routes
        to the only tenant of a single-tenant fleet (and raises on a
        multi-tenant one — ambiguous routing is an error, not a guess).
        Validation, backpressure (``block``/``timeout``/``QueueFull``) and
        cancellation semantics are exactly the tenant engine's own
        ``submit`` contract.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is shut down")
            name = request.tenant
            if name is None:
                if len(self._tenants) != 1:
                    raise ValueError(
                        f"request.tenant is required on a fleet with "
                        f"{len(self._tenants)} tenants "
                        f"(registered: {sorted(self._tenants)})")
                name = next(iter(self._tenants))
            tenant = self._tenants.get(name)
            if tenant is None:
                raise ValueError(
                    f"unknown tenant {name!r} "
                    f"(registered: {sorted(self._tenants)})")
        fut = tenant.engine.submit(request, block=block, timeout=timeout)
        self._work.set()
        return fut

    def publish(self, tenant: Optional[str], model, *,
                patched_points: int = 0, stale_blocks: int = 0) -> int:
        """Publish a streaming-updated model to ONE tenant's engine.

        Routes to ``tenant``'s engine and returns its new epoch number
        (``None`` routes to the only tenant of a single-tenant fleet,
        mirroring :meth:`submit`).  Tenant isolation carries over to
        epochs: a publish swaps exactly one tenant's serving model —
        every other tenant's engine keeps its epoch, its pinned tree, and
        its bit-exact outputs (pinned by ``tests/test_fleet.py``).  The
        per-engine atomicity contract is
        :meth:`PropagateEngine.publish
        <repro.serving.PropagateEngine.publish>`'s own.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("fleet is shut down")
            if tenant is None:
                if len(self._tenants) != 1:
                    raise ValueError(
                        f"tenant is required on a fleet with "
                        f"{len(self._tenants)} tenants "
                        f"(registered: {sorted(self._tenants)})")
                tenant = next(iter(self._tenants))
            t = self._tenants.get(tenant)
            if t is None:
                raise ValueError(
                    f"unknown tenant {tenant!r} "
                    f"(registered: {sorted(self._tenants)})")
        if "publish" not in t.engine.capabilities():
            raise ValueError(
                f"tenant {tenant!r} engine "
                f"({type(t.engine).__name__}) does not advertise the "
                f"'publish' capability (capabilities: "
                f"{sorted(t.engine.capabilities())})")
        return t.engine.publish(model, patched_points=patched_points,
                                stale_blocks=stale_blocks)

    # ----------------------------------------------------------- scheduling
    def step_round(self) -> int:
        """One deficit-round-robin pass over the tenants; futures resolved.

        Visits tenants in registration order: a backlogged tenant earns
        ``quantum * weight`` credit and dispatches microbatches while the
        credit lasts (cost: one unit per future its dispatch resolves —
        completions, failures, and expired fast-fails all consume queue
        service, so all are charged); an idle tenant's credit resets.
        This is the whole fleet scheduler — the background thread calls
        the same code — so tests drive it deterministically.
        """
        with self._lock:
            tenants = list(self._tenants.values())
            self._rounds += 1
        resolved = 0
        for t in tenants:
            if len(t.engine._queue) == 0:
                t.deficit = 0.0  # classic DRR: idle tenants bank no credit
                continue
            t.deficit += self.quantum * t.weight
            while t.deficit >= 1.0 and len(t.engine._queue) > 0:
                served = t.engine.step()
                if served == 0:
                    break  # backlog was all cancelled entries
                t.deficit -= served
                with self._lock:
                    t.served += served
                resolved += served
        return resolved

    def flush(self) -> int:
        """DRR rounds until every tenant queue drains; futures resolved.

        Unlike a single engine's snapshot-bounded ``flush``, the fleet
        flush is a teardown/test helper: it assumes producers have stopped
        (``shutdown(wait=True)`` has already closed intake) and simply
        runs rounds to empty.
        """
        total = 0
        while True:
            with self._lock:
                backlog = sum(len(t.engine._queue)
                              for t in self._tenants.values())
            if backlog == 0:
                return total
            served = self.step_round()
            if served == 0 and self.step_round() == 0:
                # nothing serveable left (e.g. an all-cancelled backlog)
                return total
            total += served

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                backlog = sum(len(t.engine._queue)
                              for t in self._tenants.values())
            if backlog == 0:
                # sleep until a submit wakes us (or the periodic re-check)
                self._work.wait(timeout=0.05)
                self._work.clear()
                continue
            try:
                self.step_round()
            except Exception:
                # per-request faults resolve futures inside engine.step;
                # anything reaching here is fleet-internal.  Never let the
                # only scheduler die silently: the engines already count
                # scheduler_errors for their own faults, so just back off
                # a beat and keep serving.
                import logging

                logging.getLogger(__name__).exception(
                    "fleet scheduler round failed; backing off")
                self._stop.wait(0.05)

    # -------------------------------------------------------- observability
    def metrics(self) -> FleetMetricsSnapshot:
        """Deep-copied, tenant-keyed snapshot of the whole fleet.

        Per-tenant sections are the engines' own immutable
        :class:`~repro.serving.MetricsSnapshot` objects plus the fleet's
        weight/served accounting — all copied at snapshot time, sharing no
        mutable structure with the live scheduler (see
        :class:`FleetMetricsSnapshot`).
        """
        with self._lock:
            tenants = dict(self._tenants)
            rounds = self._rounds
            served = {name: t.served for name, t in tenants.items()}
            weights = {name: t.weight for name, t in tenants.items()}
        return FleetMetricsSnapshot(
            tenants={name: t.engine.metrics() for name, t in tenants.items()},
            weights=copy.deepcopy(weights),
            served=copy.deepcopy(served),
            rounds=rounds,
            fair_share_err=_fair_share_err(served, weights),
        )

    # ----------------------------------------------------------- lifecycle
    def shutdown(self, wait: bool = True) -> None:
        """Stop intake fleet-wide; serve (``wait=True``) or cancel backlogs.

        Idempotent.  The fleet thread (if any) is joined first, so after
        return no dispatch is in flight anywhere; then every tenant engine
        shuts down with the same ``wait`` semantics it would honor alone
        (``wait=False`` still resolves already-expired EDF entries with the
        pinned ``DeadlineExceeded``).
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        self._work.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if wait:
            self.flush()
        with self._lock:
            tenants = list(self._tenants.values())
        for t in tenants:
            t.engine.shutdown(wait=wait)

    def __enter__(self) -> "EngineFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=exc == (None, None, None))
