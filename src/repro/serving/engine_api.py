"""The abstract serving-engine contract every LP engine implements.

This is the repo's counterpart of JetStream's ``engine_api.py``: a formal
API boundary between *what a serving engine promises* (this module) and
*how one particular engine delivers it* (``serving/_engine.py``'s
:class:`~repro.serving.PropagateEngine`, the continuous-batching engine
over one fitted variational dual tree).  Everything above the engine — the
multi-tenant :class:`~repro.serving.fleet.EngineFleet`, benchmarks,
examples — programs against :class:`Engine`, so a sharded multi-device
engine or a shared-memory multi-process engine can slot in underneath
without touching the routing/fair-queueing layer.

Params / state separation
-------------------------
An engine's data splits into two halves with very different lifecycles,
and the API keeps them formally apart:

* :attr:`Engine.fit_params` (:class:`FitParams`) — the **immutable fitted
  half**: the variational dual tree, its q distribution, dispatch buffers.
  Fitting is the expensive offline step (the paper's premise is that ONE
  fitted tree amortizes across millions of random-walk queries), and
  nothing on the serving path ever writes to it — which is exactly what
  makes it shareable: across engines in one process today, across worker
  processes via shared memory or across devices via ``jax.sharding``
  tomorrow.
* :attr:`Engine.dispatch_state` (:class:`DispatchState`) — the **mutable
  serving half**: the bounded request queue, pooled host staging buffers,
  and the metrics sink.  Exactly one scheduler owns it; it is never shared
  and never outlives the engine.

Slot-based results
------------------
:class:`ResultSlab` is the result layout contract (JetStream's
``ResultTokens`` idea): a dispatch resolves the whole group's answers as
**one** device-to-host array plus per-slot index metadata, because copying
a single contiguous array to host is much faster than one transfer per
request.  Each request's future then resolves to a zero-copy view into the
slab, sliced back to its true label width.
"""
from __future__ import annotations

import abc
import dataclasses
from concurrent.futures import Future
from typing import Any, Mapping, Optional, Sequence

import numpy as np

from repro.serving._batching import PropagateRequest
from repro.serving._metrics import MetricsSnapshot

__all__ = ["DispatchState", "Engine", "FitParams", "ResultSlab"]


@dataclasses.dataclass(frozen=True)
class FitParams:
    """The immutable fitted half of an engine (see module docstring).

    ``model`` is the fitted object every dispatch reads (for the VDT
    engine: the :class:`~repro.core.vdt.VariationalDualTree`, whose block
    structure, q distribution, and cached device dispatch buffers are all
    frozen at fit time).  ``n_points`` and ``divergence`` are the two
    pieces of fitted identity the serving layer itself consumes: the
    request-shape contract and the compile-cache key component.

    ``epoch`` is the model version under streaming updates
    (``core/streaming.py``): each :meth:`Engine.publish
    <repro.serving.PropagateEngine.publish>` of an incrementally mutated
    tree replaces the engine's ``fit_params`` with a NEW immutable
    instance at the next epoch number — the params object itself never
    mutates, so anything holding epoch ``e``'s ``FitParams`` keeps
    serving epoch ``e`` bit-identically.
    """

    model: Any
    n_points: int
    divergence: str
    epoch: int = 0


@dataclasses.dataclass
class DispatchState:
    """Live handles to the mutable serving half of an engine.

    These are the engine's working structures, not copies: ``queue`` is
    the bounded request queue, ``staging`` the pooled host staging buffers
    keyed by ``(n_points, batch bucket, width bucket)`` — ``n_points``
    because epochs published by streaming updates may change the point
    count, and a buffer sized for one epoch's ``N`` cannot stage
    another's — and ``metrics`` the mutable event sink behind
    :meth:`Engine.metrics` snapshots.  The contract is ownership, not
    thread-safety: exactly one scheduler drives this state, and sharing it
    between schedulers (unlike :class:`FitParams`, which is freely
    shareable) is a bug.
    """

    queue: Any
    staging: Mapping[tuple[int, int, int], np.ndarray]
    metrics: Any


@dataclasses.dataclass(frozen=True)
class ResultSlab:
    """One dispatch's answers as a single host array + slot metadata.

    ``data`` is the dispatch's full ``(slots, N, width bucket)`` output,
    copied device-to-host **once** for the whole group.  ``widths[k]`` is
    slot ``k``'s true label width (``<=`` the bucket; padding columns and
    padding slots hold zeros).  :meth:`view` hands out per-request answers
    as zero-copy numpy views into that one array.
    """

    data: np.ndarray
    widths: tuple[int, ...]

    @property
    def slots(self) -> int:
        """Number of real (non-padding) request slots in the slab."""
        return len(self.widths)

    def view(self, slot: int) -> np.ndarray:
        """Slot ``slot``'s ``(N, widths[slot])`` answer — a view, not a copy."""
        if not 0 <= slot < len(self.widths):
            raise IndexError(
                f"slot {slot} out of range for a {len(self.widths)}-slot slab")
        return self.data[slot, :, : self.widths[slot]]


class Engine(abc.ABC):
    """Abstract continuous-batching LP serving engine.

    The contract (see the module docstring for the params/state split and
    the slot-based result layout):

    * :meth:`submit` is thread-safe, validates at the call site (pinned
      ``ValueError`` via :meth:`PropagateRequest.validate
      <repro.serving._batching.PropagateRequest.validate>`; ``QueueFull``
      as backpressure), and returns a per-request
      :class:`~concurrent.futures.Future` resolving to the ``(N, C)``
      answer;
    * exactly one scheduler drives dispatches — a background thread, an
      external owner calling :meth:`step`/:meth:`flush` (how the fleet and
      the deterministic tests drive engines), never both;
    * :meth:`warmup` pre-compiles the reachable executable grid so
      production traffic never stalls on a compile;
    * :meth:`metrics` returns an immutable snapshot that never aliases
      live mutable state;
    * :meth:`shutdown` is idempotent; engines are context managers
      (``__exit__`` serves the backlog on clean exit, cancels it when
      unwinding an exception).
    """

    # ------------------------------------------------------- data halves
    @property
    @abc.abstractmethod
    def fit_params(self) -> FitParams:
        """The immutable fitted half — shareable, never written at serve time."""

    @property
    @abc.abstractmethod
    def dispatch_state(self) -> DispatchState:
        """The mutable serving half — owned by exactly one scheduler."""

    # --------------------------------------------------------- serving
    @abc.abstractmethod
    def submit(self, request: PropagateRequest, *, block: bool = True,
               timeout: Optional[float] = None) -> Future:
        """Enqueue one validated request; future of its ``(N, C)`` answer."""

    @abc.abstractmethod
    def warmup(self, widths: Optional[Sequence[int]] = None,
               n_iters: Sequence[int] = (500,),
               backends: Optional[Sequence[str]] = None) -> int:
        """Pre-compile the reachable dispatch grid; returns executables warmed."""

    @abc.abstractmethod
    def step(self) -> int:
        """One synchronous scheduler iteration; returns futures resolved."""

    @abc.abstractmethod
    def flush(self) -> int:
        """Serve the backlog present at call time; returns futures resolved."""

    # ----------------------------------------------------- introspection
    def capabilities(self) -> frozenset[str]:
        """The optional behaviors this engine instance actually provides.

        Capability introspection is the API's replacement for ``hasattr``
        probing: layers above an engine (the fleet's ``publish`` routing,
        preemption-aware load generators, operators' dashboards) ask the
        engine what it can do instead of guessing from its type or its
        attribute dict.  The vocabulary:

        * ``"publish"`` — epoch-versioned model swaps (:meth:`publish`
          honors the atomic-swap contract instead of raising);
        * ``"preempt"`` — segmented preemptible dispatch: long scans
          yield at segment boundaries to urgent arrivals, with the
          carry extractable bit-identically at every boundary;
        * ``"grf"`` — serves the Monte-Carlo walker backend
          (``backend="grf"`` requests are accepted);
        * ``"sharded"`` — dispatch state and label stacks are partitioned
          across a multi-device mesh (SPMD serving).

        The set reflects this *instance*'s live configuration, not just
        its class: e.g. an engine only reports ``"preempt"`` when its
        policy/segmenting configuration actually preempts.  The base
        implementation promises nothing; concrete engines override.
        """
        return frozenset()

    # -------------------------------------------------------- streaming
    def publish(self, model: Any, *, patched_points: int = 0,
                stale_blocks: int = 0) -> int:
        """Swap in a streaming-updated model as a new epoch; returns it.

        Optional capability (engines without online updates need not
        override).  The contract for engines that do: the swap is atomic
        with respect to :meth:`submit` — every already-queued or in-flight
        entry completes bit-identically against the epoch it was submitted
        under, every submit returning after ``publish`` sees the new
        epoch, and an old epoch's device/staging resources are released
        once its last entry resolves.
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not support epoch publishing")

    # ------------------------------------------------------ observability
    @abc.abstractmethod
    def metrics(self) -> MetricsSnapshot:
        """Immutable point-in-time snapshot of engine health."""

    # --------------------------------------------------------- lifecycle
    @abc.abstractmethod
    def shutdown(self, wait: bool = True) -> None:
        """Stop intake; serve (``wait=True``) or cancel the backlog."""

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=exc == (None, None, None))
