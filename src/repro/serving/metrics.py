"""Deprecated shim: import from :mod:`repro.serving` instead.

The metrics implementation moved to the private ``repro.serving._metrics``
module; this module re-exports the historical names so existing imports
keep working, with a :class:`DeprecationWarning` at import time.  The
public snapshot type (``MetricsSnapshot``) is re-exported from
:mod:`repro.serving`; the mutable sink (``EngineMetrics``) is
engine-internal.
"""
import warnings

from repro.serving._metrics import (LATENCY_WINDOW, EngineMetrics,
                                    MetricsSnapshot)

warnings.warn(
    "repro.serving.metrics is deprecated; import MetricsSnapshot from "
    "repro.serving (the mutable sink lives in repro.serving._metrics)",
    DeprecationWarning, stacklevel=2)

__all__ = ["EngineMetrics", "LATENCY_WINDOW", "MetricsSnapshot"]
