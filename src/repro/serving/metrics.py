"""Deprecated shim: import from :mod:`repro.serving` instead.

The metrics implementation moved to the private ``repro.serving._metrics``
module; this module re-exports the historical names so existing imports
keep working, with a once-per-process :class:`DeprecationWarning` at
import time.  The public snapshot type (``MetricsSnapshot``) is re-exported from
:mod:`repro.serving`; the mutable sink (``EngineMetrics``) is
engine-internal.
"""
from repro.serving._deprecation import warn_once
from repro.serving._metrics import (LATENCY_WINDOW, EngineMetrics,
                                    MetricsSnapshot)

warn_once(
    "repro.serving.metrics",
    "import MetricsSnapshot from repro.serving (the mutable sink lives in "
    "repro.serving._metrics)")

__all__ = ["EngineMetrics", "LATENCY_WINDOW", "MetricsSnapshot"]
