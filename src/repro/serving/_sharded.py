"""Sharded multi-device serving engine: the same scheduler, SPMD math.

:class:`ShardedPropagateEngine` is the second concrete implementation of
the :class:`~repro.serving.engine_api.Engine` contract.  It subclasses
:class:`~repro.serving.PropagateEngine` and overrides exactly the two
device-math hooks (``_scan`` / ``_scan_resume``), so the entire
scheduler — queue disciplines, width/batch bucketing, segmented EDF
preemption, epoch pinning, refcounted retirement, metrics — is inherited
verbatim and every dispatch runs SPMD over a 1-D device mesh instead.

Data placement (``distributed/sharding.py::leaf_mesh`` / ``leaf_sharding``)
---------------------------------------------------------------------------
Leaf-order arrays — the scattered label stack ``(n_leaves, K)`` and the
ghost-leaf mask — are row-sharded over the ``"leaves"`` mesh axis with a
``NamedSharding``; the (small) block lists ``a``/``b``, the q weights and
the per-column alpha row are replicated.  Both scans are ``shard_map``
bodies wrapped in ``jit`` with explicit input/output shardings, so device
placement is part of the compiled executable, not a runtime reshard.

Bit parity with the single-device engine
----------------------------------------
The serving contract is *bit* parity, not tolerance parity, and it is met
by construction:

* **VDT backend** — a power-of-two device count D = 2^k makes every
  device own one aligned depth-(L-k) subtree of the perfect partition
  tree.  CollectUp runs locally per subtree (the identical pairwise
  summation tree), ONE all-gather shares the per-shard partial trees, and
  the top k levels are summed from the gathered subtree roots — again the
  identical pairwise adds, pinned against XLA re-association by the
  ``optimization_barrier`` inside :func:`~repro.core.matvec.collect_up`.
  The per-block contraction ``c = q * T[b]`` + segment-sum is computed
  replicated (it is O(|B|), tiny, and identical on every device — no psum
  anywhere), and DistributeDown walks the replicated top-k prefix then
  slices into the device's own subtree.  Every float add happens in the
  same order as the single-device program.
* **Exact backend** — rows of the streamed transition matrix are
  independent, so each device runs the fused Pallas kernel over its own
  row stripe against the full column space (one all-gather of the folded
  carry per iteration).  ALL tile sizes are kept identical to the
  single-device kernel: the column tiling (``block_n``, padded size
  ``sp``) determines each row's online-softmax association order, and
  the row-block size ``block_m`` selects the matmul lowering for the
  ``p @ y`` contraction (a smaller M measurably changes bits for some
  widths).  Each device's stripe is therefore padded *locally* up to the
  256-row tile — the blocked layout — and the pad rows' outputs are
  simply discarded.  The stripe's global row offset rides into the
  kernel (``row_base``) so the self-transition diagonal masks the same
  entries it does in the whole-matrix grid.

Both resume twins use a dynamic ``fori_loop`` bound exactly like the
single-device engine, so segmented EDF preemption re-enters the very same
per-iteration program and the PR-6 carry guarantee (pause/resume is
bit-identical to never pausing) holds across the mesh.

CPU story: ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set
before importing jax) makes all of this testable on one CI host; with a
single visible device the engine degenerates to a 1-device mesh and still
exercises the full SPMD code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.matvec import collect_up, fold_batch, unfold_batch
from repro.distributed.sharding import LEAF_AXIS, leaf_mesh, leaf_sharding
from repro.serving._engine import PropagateEngine

__all__ = ["ShardedPropagateEngine"]

_BLOCK = 256  # exact-kernel tile (rows AND cols); MUST match single-device


def _to_blocked(y, D: int, rps: int, mp_loc: int, pad_value=0.0):
    """``(D*rps, k) -> (D*mp_loc, k)``: pad each device's ``rps``-row
    stripe up to the ``mp_loc`` row tile so a row-sharded array hands every
    device a whole number of 256-row kernel blocks.  Identity when the
    stripe already tiles evenly."""
    if mp_loc == rps:
        return y
    y = y.reshape(D, rps, y.shape[-1])
    y = jnp.pad(y, ((0, 0), (0, mp_loc - rps), (0, 0)),
                constant_values=pad_value)
    return y.reshape(D * mp_loc, y.shape[-1])


def _from_blocked(y, D: int, rps: int, mp_loc: int):
    """Inverse of :func:`_to_blocked`: drop each stripe's local pad rows."""
    if mp_loc == rps:
        return y
    return y.reshape(D, mp_loc, y.shape[-1])[:, :rps].reshape(
        D * rps, y.shape[-1])


def _sharded_matvec(y_sh, a, b, q, *, L: int, K: int, axis: str):
    """Per-shard Algorithm-1 matvec: local CollectUp, one all-gather,
    replicated block contraction, subtree DistributeDown.

    ``y_sh`` is this device's ``(n_leaves/D, C)`` leaf stripe; returns the
    matching stripe of (QY).  ``K = log2(D)``; levels ``0..K`` of the tree
    are computed/walked replicated, levels below live shard-local.
    """
    Lloc = L - K
    t_loc = collect_up(y_sh, Lloc)                 # (2*Nl - 1, C) local tree
    if K == 0:
        t_full = t_loc
    else:
        t_all = jax.lax.all_gather(t_loc, axis)    # (D, 2*Nl - 1, C)
        # subtree roots are the full tree's level-K nodes; summing them up
        # reproduces levels 0..K with the same pairwise adds
        top = collect_up(t_all[:, 0, :], K)        # (2D - 1, C)
        parts = [top]
        for j in range(1, Lloc + 1):
            lo, hi = (1 << j) - 1, (1 << (j + 1)) - 1
            parts.append(t_all[:, lo:hi, :].reshape(-1, t_all.shape[-1]))
        t_full = jnp.concatenate(parts, axis=0)    # (n_nodes, C) level-major
    n_nodes = (1 << (L + 1)) - 1
    # per-block contraction + segment-sum: O(|B| C), replicated — every
    # device computes the identical c_node, so no psum is ever needed
    c_block = q[:, None] * jnp.take(t_full, b, axis=0)
    c_node = jax.ops.segment_sum(c_block, a, num_segments=n_nodes)
    # DistributeDown: replicated down to level K, then into our subtree
    acc = c_node[0:1, :]
    d = jax.lax.axis_index(axis)
    for lvl in range(L):
        lo, hi = (1 << (lvl + 1)) - 1, (1 << (lvl + 2)) - 1
        if lvl < K:
            acc = jnp.repeat(acc, 2, axis=0) + c_node[lo:hi, :]
            if lvl == K - 1:
                acc = jax.lax.dynamic_slice_in_dim(acc, d, 1, axis=0)
        else:
            width = 1 << (lvl + 1 - K)
            mine = jax.lax.dynamic_slice_in_dim(
                c_node[lo:hi, :], d * width, width, axis=0)
            acc = jnp.repeat(acc, 2, axis=0) + mine
    return acc


class ShardedPropagateEngine(PropagateEngine):
    """Multi-device SPMD :class:`~repro.serving.PropagateEngine`.

    Same constructor surface as the single-device engine plus ``devices``
    (default: all visible devices; must be a power-of-two count).  The grf
    walker backend is not served — its complete kernel graph is dense and
    does not shard along leaves — so ``capabilities()`` reports
    ``{"publish", "sharded"}`` (plus ``"preempt"`` under the EDF/segmented
    configuration) and grf submits are rejected at the call site.
    """

    def __init__(self, vdt, *, devices=None, **kwargs):
        if kwargs.get("backend") == "grf":
            raise ValueError(
                "ShardedPropagateEngine does not serve backend='grf' "
                "(the walker estimator's kernel graph does not shard "
                "along leaves); use PropagateEngine")
        self._mesh = leaf_mesh(devices)
        self._axis = LEAF_AXIS
        self.n_devices = int(self._mesh.shape[LEAF_AXIS])
        if self.n_devices > _BLOCK:
            raise ValueError(
                f"ShardedPropagateEngine supports at most {_BLOCK} "
                f"devices (row-striping granularity of the exact "
                f"kernel), got {self.n_devices}")
        self._K = self.n_devices.bit_length() - 1
        self._row_sharding = leaf_sharding(self._mesh)
        self._rep_sharding = NamedSharding(self._mesh, P())
        # jitted SPMD executables keyed on their closure statics; jax.jit
        # handles per-shape caching underneath each entry
        self._jit_cache: dict = {}
        # per-epoch device buffers keyed id(vdt) — the epoch record pins
        # the tree, and _retire_locked() drops our entry with it
        self._buf_cache: dict[int, dict] = {}
        self._check_model(vdt)
        super().__init__(vdt, **kwargs)

    # ----------------------------------------------------- introspection
    def capabilities(self) -> frozenset[str]:
        """Publish/preempt as configured, ``"sharded"``, never ``"grf"``."""
        return (super().capabilities() - {"grf"}) | {"sharded"}

    # --------------------------------------------------------- lifecycle
    def _check_model(self, vdt) -> None:
        n_leaves = int(vdt.tree.n_leaves)
        if self.n_devices > n_leaves:
            raise ValueError(
                f"cannot shard a {n_leaves}-leaf tree over "
                f"{self.n_devices} devices: each device must own at "
                f"least one leaf")

    def publish(self, model, *, patched_points: int = 0,
                stale_blocks: int = 0) -> int:
        """Epoch swap with the inherited atomicity contract; the new tree
        must still divide over the mesh (collective only in the sense that
        later dispatches against the new epoch are; the swap itself is a
        host-side pointer swap exactly like the base engine's)."""
        self._check_model(model)
        return super().publish(model, patched_points=patched_points,
                               stale_blocks=stale_blocks)

    def _retire_locked(self) -> None:
        super()._retire_locked()
        live = {id(ep.vdt) for ep in self._epochs.values()}
        live.add(id(self.vdt))
        for key in [k for k in self._buf_cache if k not in live]:
            del self._buf_cache[key]

    # --------------------------------------------------- per-epoch buffers
    def _buffers(self, vdt) -> dict:
        buf = self._buf_cache.get(id(vdt))
        if buf is None:
            a, b, active, q, mask = vdt._dispatch_buffers()
            tree = vdt.tree
            # place once per epoch: block lists / q replicated over the
            # mesh, the ghost mask row-sharded with the label stripes
            rep, row = self._rep_sharding, self._row_sharding
            buf = {"L": int(tree.L), "n_leaves": int(tree.n_leaves),
                   "slot_of": tree.slot_of,
                   "a": jax.device_put(a, rep), "b": jax.device_put(b, rep),
                   "q": jax.device_put(q, rep),
                   "mask": jax.device_put(mask, row)}
            self._buf_cache[id(vdt)] = buf
        return buf

    def _exact_buffers(self, vdt) -> dict:
        buf = self._buffers(vdt)
        if "xp" not in buf:
            # deferred so constructing the engine never pulls the Pallas
            # toolchain unless the exact backend is actually dispatched
            from repro.core.divergence import resolve_divergence
            from repro.kernels.fused_lp.fused_lp import tile_config

            div = resolve_divergence(vdt.bound_divergence.div)
            tile_fn, pad, transform = tile_config(div)
            xr = vdt.x_rows
            if transform is not None:
                xr = transform(xr)
            n = int(xr.shape[0])
            # identical column padding to the single-device fused scan:
            # sp is part of each row's online-softmax association order
            sp = -(-n // _BLOCK) * _BLOCK
            D = self.n_devices
            rps = sp // D                       # rows per shard (stripe)
            mp_loc = -(-rps // _BLOCK) * _BLOCK  # stripe padded to row tile
            xp = jnp.pad(xr, ((0, sp - n), (0, 0)), constant_values=pad)
            # the padded points enter the scan twice: as each device's own
            # blocked row stripe and as the replicated column set
            buf["xp_row"] = jax.device_put(
                _to_blocked(xp, D, rps, mp_loc, pad_value=pad),
                self._row_sharding)
            buf["xp_rep"] = jax.device_put(xp, self._rep_sharding)
            buf["sp"] = sp
            buf["rps"] = rps
            buf["mp_loc"] = mp_loc
            buf["n_valid"] = n
            buf["div_name"] = div.name
            buf["tile_fn"] = tile_fn
            buf["inv"] = float(
                1.0 / (2.0 * float(vdt.sigma) * float(vdt.sigma)))
        return buf

    # ------------------------------------------------- jitted SPMD scans
    def _jit_sharded(self, body, n_sharded: int, n_rep: int):
        """``shard_map`` + ``jit`` with explicit input/output shardings:
        the first ``n_sharded`` args row-sharded over leaves, the rest
        replicated; the result row-sharded."""
        row = P(self._axis, None)
        mapped = shard_map(
            body, self._mesh,
            in_specs=tuple([row] * n_sharded + [P()] * n_rep),
            out_specs=row, check_rep=False)
        return jax.jit(
            mapped,
            in_shardings=tuple([self._row_sharding] * n_sharded
                               + [self._rep_sharding] * n_rep),
            out_shardings=self._row_sharding)

    def _vdt_scan(self, L: int, n_iters: int):
        key = ("vdt", L, int(n_iters))
        fn = self._jit_cache.get(key)
        if fn is None:
            K, axis = self._K, self._axis

            def body(y0_sh, mask_sh, a, b, q, alpha):
                def step(y, _):
                    y = mask_sh * (alpha * _sharded_matvec(
                        y, a, b, q, L=L, K=K, axis=axis)) \
                        + (1.0 - alpha) * y0_sh
                    return y, None
                y, _ = jax.lax.scan(step, y0_sh, None, length=int(n_iters))
                return y

            fn = self._jit_sharded(body, n_sharded=2, n_rep=4)
            self._jit_cache[key] = fn
        return fn

    def _vdt_resume(self, L: int):
        key = ("vdt_resume", L)
        fn = self._jit_cache.get(key)
        if fn is None:
            K, axis = self._K, self._axis

            # n_it is a dynamic fori_loop bound, mirroring the
            # single-device resume: one executable per shape covers every
            # segment length the scheduler can slice
            def body(y_sh, y0_sh, mask_sh, a, b, q, alpha, n_it):
                def it(_, y):
                    return mask_sh * (alpha * _sharded_matvec(
                        y, a, b, q, L=L, K=K, axis=axis)) \
                        + (1.0 - alpha) * y0_sh
                return jax.lax.fori_loop(0, n_it, it, y_sh)

            fn = self._jit_sharded(body, n_sharded=3, n_rep=5)
            self._jit_cache[key] = fn
        return fn

    def _exact_body(self, buf: dict):
        """One fused eq.-15 step over this device's blocked row stripe.

        The per-device carry is the ``(mp_loc, K)`` blocked stripe; each
        step all-gathers the stripes' REAL rows back into the full
        ``(sp, K)`` folded carry (bitwise the single-device carry,
        including the mid-scan epilogue garbage on global pad rows) and
        runs the kernel with the very same 256x256 tiles the single-device
        scan uses — only the row grid is shorter."""
        axis = self._axis
        n_valid, inv = buf["n_valid"], buf["inv"]
        rps, tile_fn = buf["rps"], buf["tile_fn"]
        interpret = jax.default_backend() != "tpu"
        from repro.kernels.fused_lp.batched import _folded_call

        def step(x_rows, x_full, y_sh, y0_sh, al, row_base):
            y_full = jax.lax.all_gather(y_sh[:rps], axis, axis=0, tiled=True)
            return _folded_call(
                x_rows, x_full, y_full, y0_sh, al,
                inv_two_sigma_sq=inv, n_valid=n_valid,
                block_m=_BLOCK, block_n=_BLOCK,
                interpret=interpret, tile_fn=tile_fn, row_base=row_base)

        return step

    def _exact_scan(self, buf: dict, n_iters: int):
        key = ("exact", buf["sp"], buf["n_valid"], buf["inv"],
               buf["div_name"], int(n_iters))
        fn = self._jit_cache.get(key)
        if fn is None:
            axis, rps = self._axis, buf["rps"]
            one = self._exact_body(buf)

            def body(x_rows, y0_sh, x_full, al):
                rb = jax.lax.axis_index(axis) * rps

                def step(y_sh, _):
                    return one(x_rows, x_full, y_sh, y0_sh, al, rb), None
                y, _ = jax.lax.scan(step, y0_sh, None, length=int(n_iters))
                return y

            fn = self._jit_sharded(body, n_sharded=2, n_rep=2)
            self._jit_cache[key] = fn
        return fn

    def _exact_resume(self, buf: dict):
        key = ("exact_resume", buf["sp"], buf["n_valid"], buf["inv"],
               buf["div_name"])
        fn = self._jit_cache.get(key)
        if fn is None:
            axis, rps = self._axis, buf["rps"]
            one = self._exact_body(buf)

            def body(y_sh, y0_sh, x_rows, x_full, al, n_it):
                rb = jax.lax.axis_index(axis) * rps
                return jax.lax.fori_loop(
                    0, n_it,
                    lambda _, y: one(x_rows, x_full, y, y0_sh, al, rb),
                    y_sh)

            fn = self._jit_sharded(body, n_sharded=3, n_rep=3)
            self._jit_cache[key] = fn
        return fn

    # ------------------------------------------------- device-math hooks
    @staticmethod
    def _fold(stack, alphas):
        y0 = jnp.asarray(stack)
        if not jnp.issubdtype(y0.dtype, jnp.floating):
            y0 = y0.astype(jnp.float32)
        bb, _, cb = y0.shape
        alpha = jnp.repeat(jnp.asarray(alphas, jnp.float32), cb)
        return fold_batch(y0), alpha, bb, cb

    def _scan(self, vdt, stack, alphas, n_iters: int, backend: str, *,
              n_walkers=None):
        if backend == "grf":
            raise ValueError(
                "ShardedPropagateEngine does not serve backend='grf'")
        y, alpha, bb, cb = self._fold(stack, alphas)
        row, rep = self._row_sharding, self._rep_sharding
        alpha = jax.device_put(alpha, rep)
        if backend == "vdt":
            buf = self._buffers(vdt)
            y_leaf = jnp.zeros((buf["n_leaves"], y.shape[1]), y.dtype)
            y_leaf = jax.device_put(y_leaf.at[buf["slot_of"]].set(y), row)
            out_leaf = self._vdt_scan(buf["L"], n_iters)(
                y_leaf, buf["mask"], buf["a"], buf["b"], buf["q"], alpha)
            out = out_leaf[buf["slot_of"]]
        else:
            buf = self._exact_buffers(vdt)
            sp, n = buf["sp"], buf["n_valid"]
            D, rps, mp_loc = self.n_devices, buf["rps"], buf["mp_loc"]
            y0p = jnp.pad(y, ((0, sp - n), (0, 0)))
            y0b = jax.device_put(_to_blocked(y0p, D, rps, mp_loc), row)
            al = jax.device_put(_alpha_row(alpha, y.shape[1]), rep)
            fn = self._exact_scan(buf, n_iters)
            out_b = fn(buf["xp_row"], y0b, buf["xp_rep"], al)
            out = _from_blocked(out_b, D, rps, mp_loc)[:n]
        return unfold_batch(out, bb, cb)

    def _scan_resume(self, vdt, carry, y0, alphas, n_iters, backend: str):
        if backend == "grf":
            raise ValueError(
                "backend='grf' does not support segmented resume")
        yc, alpha, bb, cb = self._fold(carry, alphas)
        ys, _, _, _ = self._fold(y0, alphas)
        row, rep = self._row_sharding, self._rep_sharding
        alpha = jax.device_put(alpha, rep)
        n_it = jax.device_put(jnp.asarray(int(n_iters), jnp.int32), rep)
        if backend == "vdt":
            buf = self._buffers(vdt)
            z = jnp.zeros((buf["n_leaves"], yc.shape[1]), yc.dtype)
            c_leaf = jax.device_put(z.at[buf["slot_of"]].set(yc), row)
            y0_leaf = jax.device_put(z.at[buf["slot_of"]].set(ys), row)
            out_leaf = self._vdt_resume(buf["L"])(
                c_leaf, y0_leaf, buf["mask"], buf["a"], buf["b"],
                buf["q"], alpha, n_it)
            out = out_leaf[buf["slot_of"]]
        else:
            buf = self._exact_buffers(vdt)
            sp, n = buf["sp"], buf["n_valid"]
            D, rps, mp_loc = self.n_devices, buf["rps"], buf["mp_loc"]
            # re-padding the carry with zeros between segments is safe:
            # the kernel's column mask keeps pad rows out of every
            # accumulation (same invariant as the single-device resume)
            ycb = jax.device_put(_to_blocked(
                jnp.pad(yc, ((0, sp - n), (0, 0))), D, rps, mp_loc), row)
            ysb = jax.device_put(_to_blocked(
                jnp.pad(ys, ((0, sp - n), (0, 0))), D, rps, mp_loc), row)
            al = jax.device_put(_alpha_row(alpha, yc.shape[1]), rep)
            fn = self._exact_resume(buf)
            out_b = fn(ycb, ysb, buf["xp_row"], buf["xp_rep"], al, n_it)
            out = _from_blocked(out_b, D, rps, mp_loc)[:n]
        return unfold_batch(out, bb, cb)


def _alpha_row(alpha, k: int):
    from repro.kernels.fused_lp.batched import _alpha_row as _ar

    return _ar(alpha, k)
