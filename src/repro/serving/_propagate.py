"""Multi-request Label-Propagation serving over one fitted VDT.

One fitted :class:`~repro.core.vdt.VariationalDualTree` can answer many
concurrent propagation queries (different seed labels, different label
widths, different alphas) — the ROADMAP's many-users story.  This module
turns a heterogeneous request list into as few batched device dispatches as
possible:

  1. requests are grouped by ``(alpha, n_iters, width bucket)`` — only
     same-recipe requests can share a ``lax.scan``.  The alpha component of
     the key is *canonicalized* (rounded to
     :data:`~repro.serving._batching.ALPHA_SIG_DIGITS` significant digits)
     so near-equal alphas coming from different clients (0.01 vs
     0.010000001) land in the same group instead of fragmenting into
     separate dispatches;
  2. within a group, each ``(N, C_r)`` label matrix is zero-padded on the
     channel axis to the bucket width ``Cb`` (the next configured bucket
     ``>= C_r``) so heterogeneous widths stack without a recompile per
     width — LP is column-independent and linear, so zero seed columns stay
     identically zero and never leak into real columns;
  3. the stacked ``(B, N, Cb)`` batch runs through the channel-folded
     batched ``label_propagate`` (one Algorithm-1 dispatch per iteration for
     the WHOLE batch), chunked at ``max_batch`` to bound device memory;
  4. answers are sliced back to each request's true width and returned in
     request order.

The request type and the whole bucketing/grouping vocabulary live in the
canonical :mod:`repro.serving._batching` module, shared with the
continuous-batching :class:`~repro.serving.PropagateEngine` (which applies
the same policy to a live queue instead of a static request list) — this
module re-exports them for its historical import surface.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.serving._batching import (ALPHA_SIG_DIGITS, DEFAULT_WIDTH_BUCKETS,
                                     PropagateRequest, bucket_width,
                                     canonical_alpha, group_key, pad_to_width,
                                     stack_group)

__all__ = [
    "ALPHA_SIG_DIGITS",
    "DEFAULT_WIDTH_BUCKETS",
    "PropagateRequest",
    "bucket_width",
    "canonical_alpha",
    "group_key",
    "pad_to_width",
    "propagate_many",
    "stack_group",
]


def propagate_many(
    vdt,
    requests: Sequence[PropagateRequest],
    *,
    buckets: Sequence[int] = DEFAULT_WIDTH_BUCKETS,
    max_batch: int = 64,
) -> list[jax.Array]:
    """Serve many LP requests against ``vdt``; results in request order.

    Each returned array has the exact ``(N, C_r)`` shape of its request's
    seed matrix.  Requests sharing ``(canonical alpha, n_iters)`` and a
    width bucket are answered by a single batched ``label_propagate``
    dispatch (chunked at ``max_batch``).  Malformed requests raise the
    pinned :meth:`PropagateRequest.validate
    <repro.serving._batching.PropagateRequest.validate>` errors up front —
    before ANY dispatch runs — tagged with the offending request index.
    """
    buckets = tuple(sorted(set(int(b) for b in buckets)))
    n = vdt.tree.n_points
    results: list[Optional[jax.Array]] = [None] * len(requests)

    groups: dict[tuple, list[tuple[int, jax.Array, int]]] = {}
    for idx, req in enumerate(requests):
        try:
            req = req.validate(n=n, buckets=buckets, default_backend="vdt")
        except ValueError as exc:
            raise ValueError(f"request {idx}: {exc}") from None
        y0 = jnp.asarray(req.y0, jnp.float32)
        c = int(y0.shape[1])
        key = group_key(req.alpha, req.n_iters, c, buckets, req.backend)
        groups.setdefault(key, []).append((idx, y0, c))

    for (alpha, n_iters, cb, backend), items in groups.items():
        for lo in range(0, len(items), max_batch):
            chunk = items[lo:lo + max_batch]
            stack = stack_group([y0 for _, y0, _ in chunk], cb)
            out = vdt.label_propagate(stack, alpha=alpha, n_iters=n_iters,
                                      batched=True, backend=backend)
            for k, (idx, _, c) in enumerate(chunk):
                results[idx] = out[k, :, :c]
    return results  # type: ignore[return-value]
