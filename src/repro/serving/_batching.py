"""Canonical request/coalescing vocabulary shared by the whole serving tier.

Before this module existed, the width-bucket / group-key logic lived twice —
once in ``serving/propagate.py`` (static request lists) and once inline in
``serving/engine.py::_dispatch`` (the live scheduler) — and request
validation was scattered across ``submit`` call sites.  Everything that
decides *which requests may share a device dispatch* now lives here, once:

* :class:`PropagateRequest` — the one request type every serving entry point
  accepts, including the multi-tenant ``tenant`` routing tag, with
  :meth:`PropagateRequest.validate` pinning every bad-input error at submit
  time (bad alpha, unknown backend, non-positive deadline, shape problems)
  instead of letting it surface deep inside a batched dispatch;
* width buckets (:func:`bucket_width`, :data:`DEFAULT_WIDTH_BUCKETS`) and
  padding/stacking helpers — bounded compile-cache growth whatever widths
  users send;
* alpha canonicalization (:func:`canonical_alpha`) and the two group keys:
  :func:`group_key` (static batching: alpha joins the key because
  ``propagate_many`` dispatches one scalar alpha per group) and
  :func:`dispatch_group_key` (the engine: alpha rides as a traced per-request
  array, so only ``(n_iters, backend)`` — plus the width bucket when width
  coalescing is off — fragments a group);
* :func:`batch_bucket` — power-of-two batch-axis padding.

Tenant routing deliberately does NOT appear in any group key: the fleet
(``serving/fleet.py``) routes by tenant *above* the per-tenant engines, so
within a tenant the coalescing rules here apply unchanged — tenancy never
fragments an otherwise-coalescible batch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ALPHA_SIG_DIGITS",
    "DEFAULT_WIDTH_BUCKETS",
    "PropagateRequest",
    "batch_bucket",
    "bucket_width",
    "canonical_alpha",
    "dispatch_group_key",
    "group_key",
    "pad_to_width",
    "stack_group",
]

# powers of two keep the folded channel axis (batch * Cb) lane-friendly
DEFAULT_WIDTH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

# alphas agreeing to this many significant digits share a dispatch group:
# float32 LP cannot distinguish finer alpha differences anyway, and a raw
# float(alpha) key would let 0.01 vs 0.010000001 fragment the batch.
ALPHA_SIG_DIGITS = 6


@dataclasses.dataclass(frozen=True)
class PropagateRequest:
    """One LP query: seed labels (N, C), its recipe, and its QoS tags.

    ``alpha`` / ``n_iters`` are the propagation recipe (paper eq. 15).  The
    remaining fields are scheduler-v2 QoS tags, all optional:

    * ``priority`` — larger = more urgent; consumed by the engine's
      ``"priority"`` queue discipline (ignored by ``"fifo"``/``"edf"``).
    * ``deadline_ms`` — relative deadline from submit; under the ``"edf"``
      discipline requests are served earliest-deadline-first and fast-fail
      with :class:`~repro.serving._queue.DeadlineExceeded` once expired.
      Other disciplines still count late completions in the metrics.
    * ``backend`` — per-request transition-matrix routing: ``None`` (the
      serving default), ``"vdt"``, ``"exact"`` (e.g. validation-tagged
      traffic pinned to the ground-truth eq.-3 walk), ``"grf"`` (the
      Monte-Carlo walker estimator), or ``"auto"``; see
      :func:`repro.core.label_prop.route_backend`.
    * ``rtol`` — the request's relative accuracy target, in ``(0, 1]``.
      Consumed two ways: ``backend="auto"`` routing (a loose rtol on a
      sparse graph permits grf), and — on a grf dispatch without an
      explicit ``n_walkers`` — the walker budget is sized from it via
      :func:`repro.core.grf.walkers_for_rtol` (CLT: ``m ~= 1/rtol^2``).
      Advisory for the deterministic backends.
    * ``n_walkers`` — explicit grf walker budget (overrides ``rtol``
      sizing and the engine default).  Deliberately NOT part of the
      dispatch group key: a grf group dispatches at the MAX budget over
      its members — more walkers strictly reduces every member's variance,
      exactly like width coalescing padding to the largest bucket — so
      heterogeneous budgets never fragment a batch.
    * ``tenant`` — multi-tenant routing tag, consumed by
      :class:`~repro.serving.fleet.EngineFleet`: which registered tenant
      (fitted tree + engine + fair-queueing weight) serves this request.
      ``None`` means "the only tenant" on a single-tenant fleet and is
      ignored by a bare :class:`~repro.serving.engine_api.Engine`.
    """
    y0: jax.Array
    alpha: float = 0.01
    n_iters: int = 500
    priority: int = 0
    deadline_ms: Optional[float] = None
    backend: Optional[str] = None
    tenant: Optional[str] = None
    rtol: Optional[float] = None
    n_walkers: Optional[int] = None

    def validate(self, *, n: int, buckets: Sequence[int],
                 default_backend: str = "vdt") -> "PropagateRequest":
        """Normalize this request for serving, or raise a pinned ValueError.

        Every way a request can be malformed surfaces HERE, at submit time,
        with a typed, stable error — never as a shape/trace failure deep
        inside a batched dispatch that would poison a whole group:

        * ``y0`` must be ``(N, C)`` with ``C`` inside a configured width
          bucket (the returned request holds a private ``float32`` copy, so
          the caller may reuse its buffer after submit);
        * ``alpha`` must be finite and in ``[0, 1]`` — eq. 15 is a convex
          combination of the walk and the seed, anything outside diverges;
        * ``n_iters`` must be a positive integer;
        * ``backend`` must resolve via
          :func:`repro.core.label_prop.route_backend` (unknown tags
          raise).  ``rtol`` feeds the ``"auto"`` rule, but an engine
          serves the *complete* fitted kernel graph (density ~1), so auto
          traffic resolves to exact/vdt — grf serving is an explicit
          per-request or engine-default tag;
        * ``rtol``, when given, must be finite and in ``(0, 1]``;
        * ``n_walkers``, when given, must be a positive integer;
        * ``deadline_ms``, when given, must be ``> 0``.

        Returns a new :class:`PropagateRequest` with the backend resolved
        to a concrete scan implementation and every field normalized to its
        canonical python type.  ``tenant`` passes through untouched — the
        fleet validates it against the registry at routing time.
        """
        from repro.core.label_prop import route_backend

        y0 = np.array(self.y0, np.float32)  # private copy, see docstring
        if y0.ndim != 2 or y0.shape[0] != n:
            raise ValueError(f"y0 must be (N={n}, C), got {y0.shape}")
        bucket_width(y0.shape[1], buckets)  # width must fit a bucket
        alpha = float(self.alpha)
        if not math.isfinite(alpha) or not 0.0 <= alpha <= 1.0:
            raise ValueError(
                f"alpha must be finite and in [0, 1] (eq. 15 is a convex "
                f"combination), got {alpha}")
        n_iters = int(self.n_iters)
        if n_iters < 1:
            raise ValueError(f"n_iters must be >= 1, got {n_iters}")
        rtol = self.rtol
        if rtol is not None:
            rtol = float(rtol)
            if not (math.isfinite(rtol) and 0.0 < rtol <= 1.0):
                raise ValueError(
                    f"rtol must be finite and in (0, 1], got {rtol}")
        n_walkers = self.n_walkers
        if n_walkers is not None:
            n_walkers = int(n_walkers)
            if n_walkers < 1:
                raise ValueError(f"n_walkers must be >= 1, got {n_walkers}")
        backend = route_backend(self.backend, default_backend, n=n,
                                rtol=rtol)
        deadline_ms = self.deadline_ms
        if deadline_ms is not None:
            deadline_ms = float(deadline_ms)
            if not deadline_ms > 0:
                raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        return PropagateRequest(
            y0=y0, alpha=alpha, n_iters=n_iters, priority=int(self.priority),
            deadline_ms=deadline_ms, backend=backend, tenant=self.tenant,
            rtol=rtol, n_walkers=n_walkers)


def bucket_width(c: int, buckets: Sequence[int]) -> int:
    """Smallest configured bucket ``>= c`` (the padded channel width)."""
    for b in buckets:
        if c <= b:
            return b
    raise ValueError(
        f"label width {c} exceeds the largest bucket {max(buckets)}; "
        f"extend `buckets` to serve wider label matrices")


def batch_bucket(n: int, cap: int) -> int:
    """Next power of two ``>= n``, capped at the configured max batch."""
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap)


def canonical_alpha(alpha: float) -> float:
    """Round ``alpha`` to :data:`ALPHA_SIG_DIGITS` significant digits.

    The canonical value is used both as the group key AND as the alpha
    actually dispatched, so two requests that group together produce
    bit-identical recipes.
    """
    return float(f"{float(alpha):.{ALPHA_SIG_DIGITS}g}")


def group_key(alpha: float, n_iters: int, c: int,
              buckets: Sequence[int],
              backend: str = "vdt") -> tuple[float, int, int, str]:
    """Static-batching group key ``(canonical alpha, n_iters, width bucket,
    backend)`` — the :func:`~repro.serving._propagate.propagate_many` policy.

    ``backend`` must already be resolved (``"vdt"`` / ``"exact"``, see
    :func:`repro.core.label_prop.route_backend`): only requests running
    against the same transition matrix can share a dispatch, and resolving
    BEFORE keying means ``None``/``"auto"`` tags that route to the same
    concrete backend never fragment an otherwise-coalescible batch.
    """
    return (canonical_alpha(alpha), int(n_iters), bucket_width(c, buckets),
            backend)


def dispatch_group_key(request: PropagateRequest, buckets: Sequence[int],
                       *, coalesce_widths: bool = True) -> tuple[int, str, int]:
    """Live-scheduler group key ``(n_iters, backend, width bucket or 0)``.

    The engine's coalescing policy: alpha NEVER joins the key (each
    request's alpha rides its dispatch as one element of a traced array),
    and with ``coalesce_widths=True`` (the default) neither does the width
    bucket — the whole group zero-pads to its largest bucket, because one
    ``lax.scan`` dispatch has a large fixed cost and a small per-column
    marginal cost.  ``request.backend`` must already be resolved (see
    :meth:`PropagateRequest.validate`).
    """
    cb = bucket_width(request.y0.shape[1], buckets)
    return (int(request.n_iters), request.backend or "vdt",
            0 if coalesce_widths else cb)


def pad_to_width(y0: jax.Array, cb: int) -> jax.Array:
    """Zero-pad ``(N, C)`` seed labels to ``(N, cb)`` on the channel axis."""
    c = y0.shape[-1]
    if c == cb:
        return y0
    return jnp.pad(y0, ((0, 0), (0, cb - c)))


def stack_group(y0s: Sequence[jax.Array], cb: int) -> jax.Array:
    """Stack same-bucket seed matrices into one ``(B, N, cb)`` batch."""
    return jnp.stack([pad_to_width(y0, cb) for y0 in y0s])
