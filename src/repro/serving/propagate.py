"""Multi-request Label-Propagation serving over one fitted VDT.

One fitted :class:`~repro.core.vdt.VariationalDualTree` can answer many
concurrent propagation queries (different seed labels, different label
widths, different alphas) — the ROADMAP's many-users story.  This module
turns a heterogeneous request list into as few batched device dispatches as
possible:

  1. requests are grouped by ``(alpha, n_iters, width bucket)`` — only
     same-recipe requests can share a ``lax.scan``.  The alpha component of
     the key is *canonicalized* (rounded to :data:`ALPHA_SIG_DIGITS`
     significant digits) so near-equal alphas coming from different clients
     (0.01 vs 0.010000001) land in the same group instead of fragmenting
     into separate dispatches;
  2. within a group, each ``(N, C_r)`` label matrix is zero-padded on the
     channel axis to the bucket width ``Cb`` (the next configured bucket
     ``>= C_r``) so heterogeneous widths stack without a recompile per
     width — LP is column-independent and linear, so zero seed columns stay
     identically zero and never leak into real columns;
  3. the stacked ``(B, N, Cb)`` batch runs through the channel-folded
     batched ``label_propagate`` (one Algorithm-1 dispatch per iteration for
     the WHOLE batch), chunked at ``max_batch`` to bound device memory;
  4. answers are sliced back to each request's true width and returned in
     request order.

Bucketing bounds compile cache growth: at most ``len(buckets)`` distinct
channel widths ever reach the jitted path, whatever widths users send.

The width-bucket policy (:data:`DEFAULT_WIDTH_BUCKETS`, :func:`bucket_width`)
is shared with the continuous-batching
:class:`~repro.serving.engine.PropagateEngine`, which applies it to a live
queue instead of a static request list.  The remaining helpers serve this
module's static batching: the engine needs neither :func:`canonical_alpha`
nor per-alpha grouping (each request's alpha rides its dispatch as one
element of a traced array) and stages into reusable buffers instead of
:func:`stack_group`'s fresh stacks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "ALPHA_SIG_DIGITS",
    "DEFAULT_WIDTH_BUCKETS",
    "PropagateRequest",
    "bucket_width",
    "canonical_alpha",
    "group_key",
    "pad_to_width",
    "propagate_many",
    "stack_group",
]

# powers of two keep the folded channel axis (batch * Cb) lane-friendly
DEFAULT_WIDTH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)

# alphas agreeing to this many significant digits share a dispatch group:
# float32 LP cannot distinguish finer alpha differences anyway, and a raw
# float(alpha) key would let 0.01 vs 0.010000001 fragment the batch.
ALPHA_SIG_DIGITS = 6


@dataclasses.dataclass(frozen=True)
class PropagateRequest:
    """One LP query: seed labels (N, C), its recipe, and its QoS tags.

    ``alpha`` / ``n_iters`` are the propagation recipe (paper eq. 15).  The
    remaining fields are scheduler-v2 QoS tags, all optional:

    * ``priority`` — larger = more urgent; consumed by the engine's
      ``"priority"`` queue discipline (ignored by ``"fifo"``/``"edf"``).
    * ``deadline_ms`` — relative deadline from submit; under the ``"edf"``
      discipline requests are served earliest-deadline-first and fast-fail
      with :class:`~repro.serving.queue.DeadlineExceeded` once expired.
      Other disciplines still count late completions in the metrics.
    * ``backend`` — per-request transition-matrix routing: ``None`` (the
      serving default), ``"vdt"``, ``"exact"`` (e.g. validation-tagged
      traffic pinned to the ground-truth eq.-3 walk), or ``"auto"``
      (exact for small N); see :func:`repro.core.label_prop.route_backend`.
    """
    y0: jax.Array
    alpha: float = 0.01
    n_iters: int = 500
    priority: int = 0
    deadline_ms: Optional[float] = None
    backend: Optional[str] = None


def bucket_width(c: int, buckets: Sequence[int]) -> int:
    """Smallest configured bucket ``>= c`` (the padded channel width)."""
    for b in buckets:
        if c <= b:
            return b
    raise ValueError(
        f"label width {c} exceeds the largest bucket {max(buckets)}; "
        f"extend `buckets` to serve wider label matrices")


def canonical_alpha(alpha: float) -> float:
    """Round ``alpha`` to :data:`ALPHA_SIG_DIGITS` significant digits.

    The canonical value is used both as the group key AND as the alpha
    actually dispatched, so two requests that group together produce
    bit-identical recipes.
    """
    return float(f"{float(alpha):.{ALPHA_SIG_DIGITS}g}")


def group_key(alpha: float, n_iters: int, c: int,
              buckets: Sequence[int],
              backend: str = "vdt") -> tuple[float, int, int, str]:
    """Dispatch-group key ``(canonical alpha, n_iters, width bucket, backend)``.

    ``backend`` must already be resolved (``"vdt"`` / ``"exact"``, see
    :func:`repro.core.label_prop.route_backend`): only requests running
    against the same transition matrix can share a dispatch, and resolving
    BEFORE keying means ``None``/``"auto"`` tags that route to the same
    concrete backend never fragment an otherwise-coalescible batch.
    """
    return (canonical_alpha(alpha), int(n_iters), bucket_width(c, buckets),
            backend)


def pad_to_width(y0: jax.Array, cb: int) -> jax.Array:
    """Zero-pad ``(N, C)`` seed labels to ``(N, cb)`` on the channel axis."""
    c = y0.shape[-1]
    if c == cb:
        return y0
    return jnp.pad(y0, ((0, 0), (0, cb - c)))


def stack_group(y0s: Sequence[jax.Array], cb: int) -> jax.Array:
    """Stack same-bucket seed matrices into one ``(B, N, cb)`` batch."""
    return jnp.stack([pad_to_width(y0, cb) for y0 in y0s])


def propagate_many(
    vdt,
    requests: Sequence[PropagateRequest],
    *,
    buckets: Sequence[int] = DEFAULT_WIDTH_BUCKETS,
    max_batch: int = 64,
) -> list[jax.Array]:
    """Serve many LP requests against ``vdt``; results in request order.

    Each returned array has the exact ``(N, C_r)`` shape of its request's
    seed matrix.  Requests sharing ``(canonical alpha, n_iters)`` and a
    width bucket are answered by a single batched ``label_propagate``
    dispatch (chunked at ``max_batch``).
    """
    from repro.core.label_prop import route_backend

    buckets = tuple(sorted(set(int(b) for b in buckets)))
    n = vdt.tree.n_points
    results: list[Optional[jax.Array]] = [None] * len(requests)

    groups: dict[tuple, list[tuple[int, jax.Array, int]]] = {}
    for idx, req in enumerate(requests):
        y0 = jnp.asarray(req.y0, jnp.float32)
        if y0.ndim != 2 or y0.shape[0] != n:
            raise ValueError(
                f"request {idx}: y0 must be (N={n}, C), got {y0.shape}")
        c = int(y0.shape[1])
        backend = route_backend(req.backend, "vdt", n=n)
        key = group_key(req.alpha, req.n_iters, c, buckets, backend)
        groups.setdefault(key, []).append((idx, y0, c))

    for (alpha, n_iters, cb, backend), items in groups.items():
        for lo in range(0, len(items), max_batch):
            chunk = items[lo:lo + max_batch]
            stack = stack_group([y0 for _, y0, _ in chunk], cb)
            out = vdt.label_propagate(stack, alpha=alpha, n_iters=n_iters,
                                      batched=True, backend=backend)
            for k, (idx, _, c) in enumerate(chunk):
                results[idx] = out[k, :, :c]
    return results  # type: ignore[return-value]
