"""Deprecated shim: import from :mod:`repro.serving` instead.

The static-batching implementation moved to the private
``repro.serving._propagate`` module (and the shared coalescing vocabulary
to ``repro.serving._batching``); this module re-exports the historical
names so existing imports keep working, with a :class:`DeprecationWarning`
at import time.
"""
import warnings

from repro.serving._batching import (ALPHA_SIG_DIGITS, DEFAULT_WIDTH_BUCKETS,
                                     PropagateRequest, bucket_width,
                                     canonical_alpha, group_key, pad_to_width,
                                     stack_group)
from repro.serving._propagate import propagate_many

warnings.warn(
    "repro.serving.propagate is deprecated; import PropagateRequest and "
    "propagate_many from repro.serving (coalescing helpers live in "
    "repro.serving._batching)",
    DeprecationWarning, stacklevel=2)

__all__ = [
    "ALPHA_SIG_DIGITS",
    "DEFAULT_WIDTH_BUCKETS",
    "PropagateRequest",
    "bucket_width",
    "canonical_alpha",
    "group_key",
    "pad_to_width",
    "propagate_many",
    "stack_group",
]
