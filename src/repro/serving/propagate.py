"""Multi-request Label-Propagation serving over one fitted VDT.

One fitted :class:`~repro.core.vdt.VariationalDualTree` can answer many
concurrent propagation queries (different seed labels, different label
widths, different alphas) — the ROADMAP's many-users story.  This module
turns a heterogeneous request list into as few batched device dispatches as
possible:

  1. requests are grouped by ``(alpha, n_iters, width bucket)`` — only
     same-recipe requests can share a ``lax.scan``;
  2. within a group, each ``(N, C_r)`` label matrix is zero-padded on the
     channel axis to the bucket width ``Cb`` (the next configured bucket
     ``>= C_r``) so heterogeneous widths stack without a recompile per
     width — LP is column-independent and linear, so zero seed columns stay
     identically zero and never leak into real columns;
  3. the stacked ``(B, N, Cb)`` batch runs through the channel-folded
     batched ``label_propagate`` (one Algorithm-1 dispatch per iteration for
     the WHOLE batch), chunked at ``max_batch`` to bound device memory;
  4. answers are sliced back to each request's true width and returned in
     request order.

Bucketing bounds compile cache growth: at most ``len(buckets)`` distinct
channel widths ever reach the jitted path, whatever widths users send.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["PropagateRequest", "propagate_many", "DEFAULT_WIDTH_BUCKETS"]

# powers of two keep the folded channel axis (batch * Cb) lane-friendly
DEFAULT_WIDTH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128)


@dataclasses.dataclass(frozen=True)
class PropagateRequest:
    """One LP query: seed labels (N, C) plus its propagation recipe."""
    y0: jax.Array
    alpha: float = 0.01
    n_iters: int = 500


def _bucket_width(c: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if c <= b:
            return b
    raise ValueError(
        f"label width {c} exceeds the largest bucket {max(buckets)}; "
        f"extend `buckets` to serve wider label matrices")


def propagate_many(
    vdt,
    requests: Sequence[PropagateRequest],
    *,
    buckets: Sequence[int] = DEFAULT_WIDTH_BUCKETS,
    max_batch: int = 64,
) -> list[jax.Array]:
    """Serve many LP requests against ``vdt``; results in request order.

    Each returned array has the exact ``(N, C_r)`` shape of its request's
    seed matrix.  Requests sharing ``(alpha, n_iters)`` and a width bucket
    are answered by a single batched ``label_propagate`` dispatch (chunked
    at ``max_batch``).
    """
    buckets = tuple(sorted(set(int(b) for b in buckets)))
    n = vdt.tree.n_points
    results: list[Optional[jax.Array]] = [None] * len(requests)

    groups: dict[tuple, list[tuple[int, jax.Array, int]]] = {}
    for idx, req in enumerate(requests):
        y0 = jnp.asarray(req.y0, jnp.float32)
        if y0.ndim != 2 or y0.shape[0] != n:
            raise ValueError(
                f"request {idx}: y0 must be (N={n}, C), got {y0.shape}")
        c = int(y0.shape[1])
        cb = _bucket_width(c, buckets)
        key = (float(req.alpha), int(req.n_iters), cb)
        groups.setdefault(key, []).append((idx, y0, c))

    for (alpha, n_iters, cb), items in groups.items():
        for lo in range(0, len(items), max_batch):
            chunk = items[lo:lo + max_batch]
            stack = jnp.stack(
                [jnp.pad(y0, ((0, 0), (0, cb - c))) for _, y0, c in chunk])
            out = vdt.label_propagate(stack, alpha=alpha, n_iters=n_iters,
                                      batched=True)
            for k, (idx, _, c) in enumerate(chunk):
                results[idx] = out[k, :, :c]
    return results  # type: ignore[return-value]
