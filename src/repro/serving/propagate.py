"""Deprecated shim: import from :mod:`repro.serving` instead.

The static-batching implementation moved to the private
``repro.serving._propagate`` module (and the shared coalescing vocabulary
to ``repro.serving._batching``); this module re-exports the historical
names so existing imports keep working, with a once-per-process
:class:`DeprecationWarning` at import time.
"""
from repro.serving._batching import (ALPHA_SIG_DIGITS, DEFAULT_WIDTH_BUCKETS,
                                     PropagateRequest, bucket_width,
                                     canonical_alpha, group_key, pad_to_width,
                                     stack_group)
from repro.serving._deprecation import warn_once
from repro.serving._propagate import propagate_many

warn_once(
    "repro.serving.propagate",
    "import PropagateRequest and propagate_many from repro.serving "
    "(coalescing helpers live in repro.serving._batching)")

__all__ = [
    "ALPHA_SIG_DIGITS",
    "DEFAULT_WIDTH_BUCKETS",
    "PropagateRequest",
    "bucket_width",
    "canonical_alpha",
    "group_key",
    "pad_to_width",
    "propagate_many",
    "stack_group",
]
