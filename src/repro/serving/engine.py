"""Deprecated shim: import from :mod:`repro.serving` instead.

The engine implementation moved to the private ``repro.serving._engine``
module when the abstract :mod:`repro.serving.engine_api` contract landed;
this module re-exports the historical names so existing imports keep
working, with a once-per-process :class:`DeprecationWarning` at import time.
"""
from repro.serving._batching import PropagateRequest
from repro.serving._deprecation import warn_once
from repro.serving._engine import PropagateEngine
from repro.serving._queue import DeadlineExceeded, QueueFull

warn_once(
    "repro.serving.engine",
    "import PropagateEngine, PropagateRequest, QueueFull, and "
    "DeadlineExceeded from repro.serving")

__all__ = ["PropagateEngine", "QueueFull", "DeadlineExceeded",
           "PropagateRequest"]
