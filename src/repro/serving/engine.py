"""Deprecated shim: import from :mod:`repro.serving` instead.

The engine implementation moved to the private ``repro.serving._engine``
module when the abstract :mod:`repro.serving.engine_api` contract landed;
this module re-exports the historical names so existing imports keep
working, with a :class:`DeprecationWarning` at import time.
"""
import warnings

from repro.serving._batching import PropagateRequest
from repro.serving._engine import PropagateEngine
from repro.serving._queue import DeadlineExceeded, QueueFull

warnings.warn(
    "repro.serving.engine is deprecated; import PropagateEngine, "
    "PropagateRequest, QueueFull, and DeadlineExceeded from repro.serving",
    DeprecationWarning, stacklevel=2)

__all__ = ["PropagateEngine", "QueueFull", "DeadlineExceeded",
           "PropagateRequest"]
