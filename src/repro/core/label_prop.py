"""Label Propagation (Zhou et al., 2003) on any transition-matrix backend.

    Y^{t+1} = alpha * P Y^t + (1 - alpha) * Y^0        (paper eq. 15)

The matvec is pluggable: VDT block matvec (O(|B|)), kNN sparse matvec
(O(kN)), dense exact (O(N^2)), or the streaming/fused kernel.  Iterations run
under ``lax.scan``.

Two entry points:

* :func:`label_propagate` — generic, takes any matvec closure.  Re-traced
  per call (the closure is fresh each time); fine for scripts and tests.
* :func:`lp_scan_leaforder` — the serving hot path.  Jitted once per
  ``(L, n_iters, shape)`` with ``alpha`` as a *traced* scalar-or-per-column
  array, so repeated serving calls hit the compile cache regardless of the
  alpha values, and requests with different alphas can share one dispatch
  (LP is column-independent, so a per-column alpha is exact).  The whole
  scan runs in leaf order: the row<->leaf permutation is applied once
  outside the scan instead of a gather + scatter per iteration.

A third entry point, :func:`lp_scan_fused`, is the **exact** counterpart of
``lp_scan_leaforder``: the same eq.-15 recursion against the exact
transition matrix P (paper eq. 3) instead of the VDT approximation Q,
served by the distance-reusing fused Pallas kernel — O(N * block) memory,
and for a batched ``(B, N, C)`` stack each pairwise-distance tile is
computed once per iteration for all B requests.  It backs
``VariationalDualTree.label_propagate(backend="exact")`` and the serving
engine's ``backend="exact"`` mode (accuracy-validation traffic at sizes
where dense P would not fit).

Segmented scans (preemptible dispatch)
--------------------------------------
Both hot-path scans have ``*_resume`` twins that enter the recursion from a
mid-walk carry instead of the seed, and ``*_segmented`` drivers that split
``n_iters`` into ``segment_iters``-sized checkpointed segments.  Eq. 15 is
a pure fixed-point iteration — ``Y^{t+1}`` depends only on ``(Y^t, Y^0,
alpha)`` — so the split is *exact*: the carry re-enters the next segment
and the composed walk is bit-identical to the monolithic scan.  The serving
engine drives segments itself (re-checking its queue between them) so a
tight-deadline arrival can preempt a long in-flight dispatch at the next
segment boundary instead of waiting out the whole scan.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matvec import mpt_matvec_leaforder

__all__ = ["one_hot_labels", "label_propagate", "lp_scan_leaforder",
           "lp_scan_leaforder_resume", "lp_scan_leaforder_segmented",
           "lp_scan_fused", "lp_scan_fused_resume", "lp_scan_fused_segmented",
           "route_backend", "AUTO_EXACT_MAX_N", "AUTO_GRF_MAX_DENSITY",
           "AUTO_GRF_MIN_RTOL", "CONCRETE_BACKENDS", "ccr"]

# `backend="auto"` routes to the exact eq.-3 scan at or below this many
# points: one exact LP iteration is O(N^2 d) streamed, which at this scale
# costs about the same as the VDT dispatch overhead, so small problems might
# as well get the ground-truth walk.  Above it, auto traffic rides the
# fitted O(|B|) approximation.  The boundary is INCLUSIVE (n == 1024 is
# exact, n == 1025 is vdt — pinned by tests/test_grf.py), and callers with
# different exact-kernel budgets may override it per call via
# ``route_backend(..., auto_exact_max_n=...)``.
AUTO_EXACT_MAX_N = 1024

# `backend="auto"` considers the GRF walker estimator only when BOTH hold
# (boundaries inclusive):
#   * the graph is sparse enough that walkers beat dense/streamed linear
#     algebra — edge fraction nnz/N^2 at most AUTO_GRF_MAX_DENSITY (the
#     per-step costs cross around deg ~= 0.05 N: one walker step is O(m)
#     per node vs O(deg) per node for a sparse matvec with m ~ 100s);
#   * the request's accuracy target tolerates Monte-Carlo noise — rtol at
#     least AUTO_GRF_MIN_RTOL, since an m-walker mean's relative error is
#     ~1/sqrt(m) (CLT) and rtol below 5% would demand m > 400 walkers,
#     past which exact/vdt wins (see core.grf.walkers_for_rtol).
# Requests that don't state density or rtol never auto-route to grf.
AUTO_GRF_MAX_DENSITY = 0.05
AUTO_GRF_MIN_RTOL = 0.05

# the three concrete scan implementations every routing tag resolves to —
# the serving tier's validate/group-key/warmup paths all share this
# vocabulary, so a new backend lands in exactly one place
CONCRETE_BACKENDS = ("vdt", "exact", "grf")


def route_backend(requested, default: str = "vdt", *, n=None,
                  density=None, rtol=None,
                  auto_exact_max_n: int = AUTO_EXACT_MAX_N) -> str:
    """Resolve a per-request backend tag to a concrete scan implementation.

    The single routing decision behind the engine's hybrid serving (and
    ``propagate_many``): every request carries ``backend`` as ``None`` (use
    the caller's ``default``), an explicit concrete tag (``"vdt"`` /
    ``"exact"`` / ``"grf"``), or ``"auto"``.  ``"auto"`` resolves by the
    documented rule, in order:

    1. ``"grf"`` iff the graph is sparse AND the accuracy target tolerates
       Monte-Carlo noise: ``density <= AUTO_GRF_MAX_DENSITY`` and
       ``rtol >= AUTO_GRF_MIN_RTOL`` (both boundaries inclusive; a
       ``None`` density or rtol disqualifies grf — no stated sparsity or
       tolerance means no walker routing);
    2. else ``"exact"`` iff ``n <= auto_exact_max_n`` (inclusive;
       override the cutoff per call for a different exact-kernel budget);
    3. else ``"vdt"``.

    Returns a member of :data:`CONCRETE_BACKENDS`; raises ``ValueError``
    on anything else so bad tags fail at submit time, not at dispatch.
    """
    if requested is None:
        requested = default
    if requested == "auto":
        if (density is not None and rtol is not None
                and float(density) <= AUTO_GRF_MAX_DENSITY
                and float(rtol) >= AUTO_GRF_MIN_RTOL):
            return "grf"
        if n is None:
            raise ValueError("backend='auto' routing needs the problem size n")
        return "exact" if int(n) <= int(auto_exact_max_n) else "vdt"
    if requested not in CONCRETE_BACKENDS:
        raise ValueError(
            f"backend must be one of {CONCRETE_BACKENDS}, 'auto' or None, "
            f"got {requested!r}")
    return requested


def one_hot_labels(
    labels: np.ndarray, labeled_mask: np.ndarray, n_classes: int
) -> jnp.ndarray:
    """Y0: one-hot rows for labeled points, zero rows otherwise."""
    y0 = jax.nn.one_hot(jnp.asarray(labels), n_classes, dtype=jnp.float32)
    return y0 * jnp.asarray(labeled_mask, jnp.float32)[:, None]


def label_propagate(
    matvec: Callable[[jax.Array], jax.Array],
    y0: jax.Array,
    alpha: float = 0.01,
    n_iters: int = 500,
) -> jax.Array:
    """Run eq. 15 for ``n_iters`` steps; returns the final label matrix."""

    def step(y, _):
        y = alpha * matvec(y) + (1.0 - alpha) * y0
        return y, None

    y, _ = jax.lax.scan(step, y0, None, length=n_iters)
    return y


@functools.partial(jax.jit, static_argnames=("L", "n_iters"))
def lp_scan_leaforder(
    y0_leaf: jax.Array,      # (Np, K) seed labels in leaf order (ghosts 0)
    leaf_mask: jax.Array,    # (Np, 1) 1.0 at real leaves, 0.0 at ghosts
    a: jax.Array,            # (cap,) block row nodes
    b: jax.Array,            # (cap,) block col nodes
    q: jax.Array,            # (cap,) exp(log_q), 0 where inactive
    alpha: jax.Array,        # () or (K,) — traced, NOT part of the jit key
    L: int,
    n_iters: int,
) -> jax.Array:
    """Eq. 15 for ``n_iters`` steps, entirely in leaf order; returns (Np, K).

    Ghost leaves receive meaningless DistributeDown path sums, so the matvec
    term is re-masked every iteration — otherwise ghost garbage would feed
    back into the next CollectUp and corrupt real rows.  ``y0_leaf`` is zero
    at ghosts by construction, so masked rows stay identically zero and the
    caller can gather real rows with ``tree.slot_of`` afterwards.
    """

    def step(y, _):
        y = leaf_mask * (alpha * mpt_matvec_leaforder(y, a, b, q, L)) \
            + (1.0 - alpha) * y0_leaf
        return y, None

    y, _ = jax.lax.scan(step, y0_leaf, None, length=n_iters)
    return y


@functools.partial(jax.jit, static_argnames=("L",))
def lp_scan_leaforder_resume(
    y_leaf: jax.Array,       # (Np, K) mid-walk carry in leaf order
    y0_leaf: jax.Array,      # (Np, K) seed labels (the eq.-15 restart term)
    leaf_mask: jax.Array,    # (Np, 1) 1.0 at real leaves, 0.0 at ghosts
    a: jax.Array,
    b: jax.Array,
    q: jax.Array,
    alpha: jax.Array,
    L: int,
    n_iters,
) -> jax.Array:
    """``n_iters`` eq.-15 steps entered from a mid-walk carry ``y_leaf``.

    The segmented-dispatch primitive behind :func:`lp_scan_leaforder`: the
    per-iteration body is identical, only the loop init differs, so
    resuming from the carry of an earlier scan continues the monolithic
    walk bit-identically (``lp_scan_leaforder(y0, ...)`` is the
    ``y_leaf == y0_leaf`` special case).  Ghost rows of the carry are zero
    by the re-masking invariant, so a carry round-tripped through row order
    between segments re-enters unchanged.

    ``n_iters`` is *traced* — a dynamic ``fori_loop`` bound — so all
    segment lengths share ONE compiled executable per ``(shape, L)``: odd
    remainder segments never stall a serving dispatch on a fresh compile,
    and XLA can never constant-fold a short tail into a differently-fused
    inline body (which is what breaks length-1 bit-parity on the fused
    path; see ``kernels/fused_lp/batched.py``).
    """

    def body(_, y):
        return leaf_mask * (alpha * mpt_matvec_leaforder(y, a, b, q, L)) \
            + (1.0 - alpha) * y0_leaf

    return jax.lax.fori_loop(0, n_iters, body, y_leaf)


def lp_scan_leaforder_segmented(
    y0_leaf: jax.Array,
    leaf_mask: jax.Array,
    a: jax.Array,
    b: jax.Array,
    q: jax.Array,
    alpha: jax.Array,
    L: int,
    n_iters: int,
    segment_iters: int,
) -> jax.Array:
    """Eq. 15 as ``ceil(n_iters / segment_iters)`` checkpointed segments.

    Bit-identical to ``lp_scan_leaforder(..., n_iters)`` — the carry of
    each segment re-enters the next via :func:`lp_scan_leaforder_resume` —
    while syncing at every segment boundary.  The parity reference for the
    engine's preemptible dispatch (which drives the same resume primitive
    but interleaves queue checks between segments).
    """
    if segment_iters < 1:
        raise ValueError(f"segment_iters must be >= 1, got {segment_iters}")
    if segment_iters >= n_iters:
        # one segment covers the walk: run the monolithic scan directly
        return lp_scan_leaforder(y0_leaf, leaf_mask, a, b, q, alpha, L,
                                 int(n_iters))
    y, done = y0_leaf, 0
    while done < n_iters:
        k = min(int(segment_iters), int(n_iters) - done)
        y = lp_scan_leaforder_resume(y, y0_leaf, leaf_mask, a, b, q, alpha,
                                     L, k)
        done += k
    return y


def lp_scan_fused(
    x: jax.Array,            # (N, d) points
    y0: jax.Array,           # (N,), (N, C) or (batch, N, C) seed labels
    sigma: float,
    alpha=0.01,
    n_iters: int = 500,
    *,
    block_m: int = 256,
    block_n: int = 256,
    divergence=None,
) -> jax.Array:
    """Eq. 15 against the EXACT transition matrix, streamed, never dense.

    The fused-kernel twin of :func:`lp_scan_leaforder`: every iteration is
    one pass of the distance-reusing Pallas kernel (see
    ``kernels/fused_lp/batched.py``), so P is never materialized and a
    batched ``(batch, N, C)`` stack pays the pairwise-distance/softmax work
    once per iteration for the whole batch, not once per request.

    ``alpha`` is traced: a scalar, per-column ``(C,)`` (2-D ``y0``), or
    per-request ``(batch,)`` (3-D ``y0``).  ``sigma``, ``n_iters``,
    ``divergence`` and the block sizes are static; repeated calls with the
    same shapes hit the jit cache — and distinct divergences always compile
    distinct executables (the divergence is part of the jit key), so mixed
    traffic cannot cross-contaminate the cache.  Returns the final labels
    in ``y0``'s shape.
    """
    # deferred so importing core never pulls the Pallas toolchain eagerly
    from repro.core.divergence import resolve_divergence
    from repro.kernels.fused_lp import fused_lp_scan_batched, fused_lp_scan_folded

    # unwrap BoundDivergence (carries tree arrays, not hashable) to the
    # hashable Divergence that rides as the static jit key
    divergence = resolve_divergence(divergence)
    y0 = jnp.asarray(y0)
    if not jnp.issubdtype(y0.dtype, jnp.floating):
        y0 = y0.astype(jnp.float32)
    sigma = float(sigma)
    if y0.ndim == 3:
        batch = y0.shape[0]
        alpha = jnp.asarray(alpha, jnp.float32)
        if alpha.ndim == 1 and alpha.shape[0] != batch:
            raise ValueError(
                f"per-request alpha wants shape ({batch},), got {alpha.shape}")
        return fused_lp_scan_batched(x, y0, sigma, alpha, int(n_iters),
                                     block_m=block_m, block_n=block_n,
                                     divergence=divergence)
    squeeze = y0.ndim == 1
    if squeeze:
        y0 = y0[:, None]
    out = fused_lp_scan_folded(x, y0, sigma, jnp.asarray(alpha, jnp.float32),
                               int(n_iters), block_m=block_m, block_n=block_n,
                               divergence=divergence)
    return out[:, 0] if squeeze else out


def lp_scan_fused_resume(
    x: jax.Array,            # (N, d) points
    y: jax.Array,            # carry, same shape family as ``y0``
    y0: jax.Array,           # (N,), (N, C) or (batch, N, C) seed labels
    sigma: float,
    alpha=0.01,
    n_iters: int = 500,
    *,
    block_m: int = 256,
    block_n: int = 256,
    divergence=None,
) -> jax.Array:
    """``n_iters`` exact eq.-15 steps entered from a mid-walk carry ``y``.

    The exact-backend segmented-dispatch primitive: same shape/alpha/static
    handling as :func:`lp_scan_fused` (which is the ``y == y0`` special
    case), but the streamed scan starts from the carry of an earlier
    segment, continuing the monolithic walk bit-identically.
    """
    from repro.core.divergence import resolve_divergence
    from repro.kernels.fused_lp import (fused_lp_scan_batched_resume,
                                        fused_lp_scan_folded_resume)

    divergence = resolve_divergence(divergence)
    y0 = jnp.asarray(y0)
    if not jnp.issubdtype(y0.dtype, jnp.floating):
        y0 = y0.astype(jnp.float32)
    y = jnp.asarray(y, y0.dtype)
    if y.shape != y0.shape:
        raise ValueError(
            f"carry shape {y.shape} must match seed shape {y0.shape}")
    sigma = float(sigma)
    if y0.ndim == 3:
        batch = y0.shape[0]
        alpha = jnp.asarray(alpha, jnp.float32)
        if alpha.ndim == 1 and alpha.shape[0] != batch:
            raise ValueError(
                f"per-request alpha wants shape ({batch},), got {alpha.shape}")
        return fused_lp_scan_batched_resume(
            x, y, y0, sigma, alpha, int(n_iters),
            block_m=block_m, block_n=block_n, divergence=divergence)
    squeeze = y0.ndim == 1
    if squeeze:
        y, y0 = y[:, None], y0[:, None]
    out = fused_lp_scan_folded_resume(
        x, y, y0, sigma, jnp.asarray(alpha, jnp.float32), int(n_iters),
        block_m=block_m, block_n=block_n, divergence=divergence)
    return out[:, 0] if squeeze else out


def lp_scan_fused_segmented(
    x: jax.Array,
    y0: jax.Array,
    sigma: float,
    alpha=0.01,
    n_iters: int = 500,
    *,
    segment_iters: int,
    block_m: int = 256,
    block_n: int = 256,
    divergence=None,
) -> jax.Array:
    """Exact eq.-15 walk as checkpointed ``segment_iters``-sized segments.

    Bit-identical to ``lp_scan_fused(..., n_iters)``; see
    :func:`lp_scan_leaforder_segmented` for the contract.
    """
    if segment_iters < 1:
        raise ValueError(f"segment_iters must be >= 1, got {segment_iters}")
    if segment_iters >= n_iters:
        # one segment covers the walk: run the monolithic scan directly
        return lp_scan_fused(x, y0, sigma, alpha, int(n_iters),
                             block_m=block_m, block_n=block_n,
                             divergence=divergence)
    y, done = y0, 0
    while done < n_iters:
        k = min(int(segment_iters), int(n_iters) - done)
        y = lp_scan_fused_resume(x, y, y0, sigma, alpha, k,
                                 block_m=block_m, block_n=block_n,
                                 divergence=divergence)
        done += k
    return y


@functools.partial(jax.jit, static_argnames=())
def _argmax(y: jax.Array) -> jax.Array:
    return jnp.argmax(y, axis=-1)


def ccr(y_final: jax.Array, labels: np.ndarray, eval_mask: np.ndarray) -> float:
    """Correct classification rate on ``eval_mask`` rows."""
    pred = np.asarray(_argmax(y_final))
    mask = np.asarray(eval_mask, bool)
    if mask.sum() == 0:
        return float("nan")
    return float((pred[mask] == np.asarray(labels)[mask]).mean())
