"""Label Propagation (Zhou et al., 2003) on any transition-matrix backend.

    Y^{t+1} = alpha * P Y^t + (1 - alpha) * Y^0        (paper eq. 15)

The matvec is pluggable: VDT block matvec (O(|B|)), kNN sparse matvec
(O(kN)), dense exact (O(N^2)), or the streaming/fused kernel.  Iterations run
under ``lax.scan``.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["one_hot_labels", "label_propagate", "ccr"]


def one_hot_labels(
    labels: np.ndarray, labeled_mask: np.ndarray, n_classes: int
) -> jnp.ndarray:
    """Y0: one-hot rows for labeled points, zero rows otherwise."""
    y0 = jax.nn.one_hot(jnp.asarray(labels), n_classes, dtype=jnp.float32)
    return y0 * jnp.asarray(labeled_mask, jnp.float32)[:, None]


def label_propagate(
    matvec: Callable[[jax.Array], jax.Array],
    y0: jax.Array,
    alpha: float = 0.01,
    n_iters: int = 500,
) -> jax.Array:
    """Run eq. 15 for ``n_iters`` steps; returns the final label matrix."""

    def step(y, _):
        y = alpha * matvec(y) + (1.0 - alpha) * y0
        return y, None

    y, _ = jax.lax.scan(step, y0, None, length=n_iters)
    return y


@functools.partial(jax.jit, static_argnames=())
def _argmax(y: jax.Array) -> jax.Array:
    return jnp.argmax(y, axis=-1)


def ccr(y_final: jax.Array, labels: np.ndarray, eval_mask: np.ndarray) -> float:
    """Correct classification rate on ``eval_mask`` rows."""
    pred = np.asarray(_argmax(y_final))
    mask = np.asarray(eval_mask, bool)
    if mask.sum() == 0:
        return float("nan")
    return float((pred[mask] == np.asarray(labels)[mask]).mean())
