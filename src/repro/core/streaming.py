"""Online insert/delete on a fitted tree — the streaming VDT layer.

A production graph is never static, but a full ``fit()`` is O(N d): every
point change would stall all traffic behind a refit.  The paper's eq.-9
subtree-statistics factorization (generalized per-divergence in
``core/divergence.py``) makes incremental maintenance cheap instead: a
point only ever contributes to the stats of its **root-to-leaf ancestor
path** — L + 1 = O(log N) nodes — so inserting or deleting k points is an
O(k d log N) bottom-up patch of ``W``/``S1``/``S2`` (and ``Sphi``/``Sg``/
``Sgx`` for non-default divergences), not a rebuild.

The q re-optimization after a patch is equally incremental: per-block
divergences are cached host-side, only *touched* blocks (a side's stats
changed, or the block's activation flipped) are recomputed — O(touched d) —
and the global optimum is then recovered through the d-free tail of the
optimizer (:func:`repro.core.qopt.optimize_q_from_g`, O(|B| + N) segment
and level sweeps).  The result is exactly the same constrained optimum a
full ``optimize_q`` would return, which is what the incremental-vs-refit
differential harness (``tests/test_streaming.py``) pins.

Copy-on-write epochs
--------------------
Mutations never modify the fitted model they are called on.  Each returns a
**new** :class:`~repro.core.vdt.VariationalDualTree` sharing no mutable
state with the old one, so a serving engine can keep dispatching in-flight
batches against the old epoch bit-identically while new submissions see
the new tree (see ``serving/_engine.py::PropagateEngine.publish``).  The
mutable float64 host mirrors ride along on the *newest* epoch only
(``vdt._stream``); mutating an older epoch transparently rebuilds them.

Mechanics
---------
* **Insert** claims zero-weight *ghost* leaf slots (``fit(capacity=...)``
  reserves headroom; deletes free slots too), routing each point down the
  tree toward the nearest child centroid among children with free slots.
  New points get fresh row ids ``N..N+k-1`` (appended in order).
  :class:`CapacityError` when no ghost slots remain.
* **Delete** subtracts the points' path contributions, zeroes their leaf
  slots (making them insertion headroom), and **compacts row ids**: the
  surviving rows keep their relative order, so the model's row ordering
  equals a from-scratch fit on the surviving points — which is what makes
  exact-backend LP parity in the differential harness tight.  Subtrees
  emptied by a delete have their stats zeroed *exactly* (no float residue),
  keyed off an integer real-leaf count per node.
* **Coverage repair**: a block partition's activity is recomputed as a pure
  function of the patched weights (:func:`repro.core.blocks.refresh_active`)
  — an insert into a formerly all-ghost subtree activates the inactive
  forest-leaf blocks covering it; a delete that empties a block's side
  deactivates it (its mass is provably zero either way).
* **Staleness**: every touched block is marked stale; ``refine()`` on the
  new model spends its block budget on stale blocks first
  (:func:`repro.core.refine.refine_topk`).
* ``sigma`` is carried over unchanged — the bandwidth is a global property
  that drifts slowly under point churn; background refinement (or a full
  refit) re-learns it.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import divergence as div_mod
from repro.core import qopt as qopt_mod
from repro.core.tree import PartitionTree
from repro.core.vdt import VariationalDualTree

__all__ = [
    "CapacityError",
    "StreamUpdate",
    "delete_points",
    "insert_points",
    "recompute",
]


class CapacityError(ValueError):
    """An insert asked for more ghost leaf slots than the tree has free.

    Reserve headroom at fit time (``VariationalDualTree.fit(x,
    capacity=...)``) or free slots with :func:`delete_points`; growing the
    leaf level itself requires a refit (the tree's heap layout is static).
    """


@dataclasses.dataclass(frozen=True)
class StreamUpdate:
    """Result of one streaming mutation.

    ``vdt`` is the new epoch (copy-on-write: the input model is untouched).
    ``rows`` are the new row ids of inserted points, or the *old* row ids
    of deleted points.  ``row_map`` (deletes only) maps every old row id to
    its compacted new id, -1 for deleted rows.  ``touched_blocks`` counts
    blocks whose divergence was recomputed; ``stale_blocks`` is the total
    now awaiting refinement priority.
    """

    vdt: VariationalDualTree
    rows: np.ndarray
    row_map: Optional[np.ndarray]
    patched_points: int
    touched_blocks: int
    stale_blocks: int


# ===================================================== host mirror state
@dataclasses.dataclass
class _StreamState:
    """Mutable float64 host mirrors of one (newest-epoch) fitted model.

    Stats accumulate in float64 so repeated add/subtract patches do not
    drift at float32 precision; the per-epoch device arrays are float32
    snapshots of these.  ``cnt`` is the integer number of real leaves per
    node — the exact-emptiness signal that lets a delete zero a subtree's
    stats with no float residue, and the free-slot count that routes
    inserts.  ``d2`` caches the block divergences of partition slots
    [0, n); ``stale`` marks slots awaiting refinement priority.
    """

    x_leaf: np.ndarray        # (Np, d) float64
    w_leaf: np.ndarray        # (Np,)  float64
    leaf_of: np.ndarray       # (Np,)  int64, ghosts -> n_points
    slot_of: np.ndarray       # (N,)   int64
    cnt: np.ndarray           # (n_nodes,) int64 real leaves per subtree
    W: np.ndarray             # (n_nodes,) float64
    S1: np.ndarray            # (n_nodes, d) float64
    S2: np.ndarray            # (n_nodes,) float64
    sphi: Optional[np.ndarray]  # (n_nodes,) float64, None for sqeuclidean
    sg: Optional[np.ndarray]    # (n_nodes, d)
    sgx: Optional[np.ndarray]   # (n_nodes,)
    d2: np.ndarray            # (cap,) float64 cached block divergences
    stale: np.ndarray         # (cap,) bool
    bp_n: int
    cap: int
    owner: "weakref.ref"      # the model these mirrors currently describe


def _node_sums_np(leaf_vals: np.ndarray) -> np.ndarray:
    """numpy twin of ``divergence._node_sums``: bottom-up heap-order sums."""
    vals = [leaf_vals]
    L = int(len(leaf_vals)).bit_length() - 1
    for _ in range(L):
        vals.append(vals[-1].reshape((-1, 2) + vals[-1].shape[1:]).sum(1))
    return np.concatenate(vals[::-1])


def _path_nodes(slots: np.ndarray, L: int) -> np.ndarray:
    """(k, L+1) heap ids of each leaf slot's root-to-leaf ancestor path."""
    slots = np.asarray(slots, np.int64)
    lv = np.arange(L + 1)
    return ((1 << lv)[None, :] - 1) + (slots[:, None] >> (L - lv)[None, :])


def _leaf_div_terms(div: div_mod.Divergence, x: np.ndarray, w: np.ndarray):
    """Per-point (w*phi, w*grad, w*<grad, x>) terms, float64 host arrays.

    Matches ``divergence._compute_stats``: out-of-domain zero-weight points
    are substituted with the divergence's pad value before phi/grad (their
    w = 0 factor keeps the contribution zero either way).
    """
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    xs = np.where((w > 0)[:, None], x, div.pad_value)
    xs32 = jnp.asarray(xs, jnp.float32)
    phi = np.asarray(div.phi(xs32), np.float64)
    g = np.asarray(div.grad_phi(xs32), np.float64)
    gx = (g * xs).sum(-1)
    return phi * w, g * w[:, None], gx * w


def _block_div_np(state: _StreamState, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Block divergences from the host mirrors (eq. 9 / its Bregman form)."""
    W, S1, S2 = state.W, state.S1, state.S2
    wa, wb = W[a], W[b]
    if state.sphi is None:  # sqeuclidean
        d = wa * S2[b] + wb * S2[a] - 2.0 * (S1[a] * S1[b]).sum(-1)
    else:
        d = (wb * state.sphi[a] - wa * state.sphi[b]
             - (S1[a] * state.sg[b]).sum(-1) + wa * state.sgx[b])
    return np.maximum(d, 0.0)


def _build_state(vdt: VariationalDualTree) -> _StreamState:
    """O(N d) one-time mirror build; amortized across later O(k d log N) ops."""
    tree = vdt.tree
    x_leaf = np.asarray(tree.x_leaf, np.float64)
    w_leaf = np.asarray(tree.w_leaf, np.float64)
    div = vdt.bound_divergence
    if div.name == "sqeuclidean":
        sphi = sg = sgx = None
    else:
        p, g, gx = _leaf_div_terms(div.div, x_leaf, w_leaf)
        sphi, sg, sgx = _node_sums_np(p), _node_sums_np(g), _node_sums_np(gx)
    bp = vdt.bp
    state = _StreamState(
        x_leaf=x_leaf,
        w_leaf=w_leaf,
        leaf_of=np.asarray(tree.leaf_of, np.int64),
        slot_of=np.asarray(tree.slot_of, np.int64),
        cnt=_node_sums_np((w_leaf > 0).astype(np.int64)),
        W=_node_sums_np(w_leaf),
        S1=_node_sums_np(x_leaf * w_leaf[:, None]),
        S2=_node_sums_np((x_leaf * x_leaf).sum(-1) * w_leaf),
        sphi=sphi,
        sg=sg,
        sgx=sgx,
        d2=np.zeros(bp.cap, np.float64),
        stale=np.zeros(bp.cap, bool),
        bp_n=bp.n,
        cap=bp.cap,
        owner=weakref.ref(vdt),
    )
    nb = bp.n
    state.d2[:nb] = _block_div_np(state, bp.a[:nb], bp.b[:nb])
    return state


def _ensure_state(vdt: VariationalDualTree) -> _StreamState:
    state = getattr(vdt, "_stream", None)
    if (state is not None and state.owner() is vdt
            and state.bp_n == vdt.bp.n and state.cap == vdt.bp.cap):
        return state
    # first mutation on this model (or a branch off / post-refine epoch):
    # rebuild the mirrors from its immutable arrays
    return _build_state(vdt)


# ========================================================= insert routing
def _route_insert(state: _StreamState, x_new: np.ndarray, L: int) -> np.ndarray:
    """Pick a free ghost leaf slot for each new point.

    Greedy descent: at each level go to the child with free leaf capacity
    whose centroid (S1/W) is nearest the point — empty subtrees sort last,
    ties prefer more free slots then the lower node id, so routing is
    deterministic.  O(d log Np) per point.
    """
    cnt, W, S1 = state.cnt, state.W, state.S1
    extra = {}  # node -> slots claimed by earlier points of this batch
    slots = np.empty(len(x_new), np.int64)
    for j, x in enumerate(np.asarray(x_new, np.float64)):
        node = 0
        for lvl in range(L):
            span = 1 << (L - lvl - 1)
            best = None
            for c in (2 * node + 1, 2 * node + 2):
                free = span - int(cnt[c]) - extra.get(c, 0)
                if free <= 0:
                    continue
                if W[c] > 0:
                    mu = S1[c] / W[c]
                    dist = float(((x - mu) ** 2).sum())
                else:
                    dist = np.inf
                key = (dist, -free, c)
                if best is None or key < best:
                    best = key
            node = best[2]
            extra[node] = extra.get(node, 0) + 1
        slots[j] = node - ((1 << L) - 1)
    return slots


# ============================================================== mutations
def insert_points(vdt: VariationalDualTree, x_new, weights=None) -> StreamUpdate:
    """Insert k points into a fitted model; returns the new epoch.

    O(k d log N) stat patching + O(touched d) divergence refresh + one
    d-free global q re-optimization — no refit.  New points take row ids
    ``N..N+k-1``.  Raises :class:`CapacityError` when fewer than k ghost
    leaf slots remain, and ``ValueError`` for shape/domain/weight problems.
    """
    tree = vdt.tree
    x_new = np.asarray(x_new, np.float32)
    if x_new.ndim == 1:
        x_new = x_new[None, :]
    if x_new.ndim != 2 or x_new.shape[1] != tree.dim:
        raise ValueError(
            f"insert_points wants (k, {tree.dim}) points, got {x_new.shape}")
    k = x_new.shape[0]
    if k == 0:
        raise ValueError("insert_points: empty point set")
    bound = vdt.bound_divergence
    bound.div.validate_domain(x_new)
    if weights is None:
        w_new = np.ones(k, np.float64)
    else:
        w_new = np.asarray(weights, np.float64).reshape(-1)
        if w_new.shape != (k,) or np.any(w_new <= 0) or not np.all(np.isfinite(w_new)):
            raise ValueError(
                f"weights must be {k} strictly positive finite values")

    state = _ensure_state(vdt)
    L, Np, n = tree.L, tree.n_leaves, tree.n_points
    free_total = Np - int(state.cnt[0])
    if k > free_total:
        raise CapacityError(
            f"insert of {k} points exceeds the tree's {free_total} free leaf "
            f"slots; refit with capacity >= {n + k} "
            f"(VariationalDualTree.fit(x, capacity=...)) or delete points "
            f"first")

    slots = _route_insert(state, x_new, L)
    rows = n + np.arange(k, dtype=np.int64)

    x64 = np.asarray(x_new, np.float64)
    state.x_leaf[slots] = x64
    state.w_leaf[slots] = w_new
    state.leaf_of[slots] = rows
    state.slot_of = np.concatenate([state.slot_of, slots])

    # bottom-up path patch: each point touches exactly its L+1 ancestors
    flat = _path_nodes(slots, L).ravel()
    rep = L + 1
    np.add.at(state.W, flat, np.repeat(w_new, rep))
    np.add.at(state.S1, flat, np.repeat(x64 * w_new[:, None], rep, axis=0))
    np.add.at(state.S2, flat, np.repeat((x64 * x64).sum(-1) * w_new, rep))
    np.add.at(state.cnt, flat, 1)
    if state.sphi is not None:
        p, g, gx = _leaf_div_terms(bound.div, x64, w_new)
        np.add.at(state.sphi, flat, np.repeat(p, rep))
        np.add.at(state.sg, flat, np.repeat(g, rep, axis=0))
        np.add.at(state.sgx, flat, np.repeat(gx, rep))

    dirty_nodes = np.zeros(tree.n_nodes, bool)
    dirty_nodes[flat] = True
    return _commit(vdt, state, dirty_nodes, rows=rows, row_map=None,
                   new_n=n + k, patched=k)


def delete_points(vdt: VariationalDualTree, rows) -> StreamUpdate:
    """Delete points by row id; returns the new epoch.

    Same O(k d log N) patch structure as :func:`insert_points`, run in
    reverse; freed leaf slots become insertion headroom.  Row ids are
    **compacted**: surviving rows keep their relative order (``row_map`` on
    the returned update maps old ids to new).  Deleting every point is an
    error — a model must keep at least one point.
    """
    tree = vdt.tree
    rows = np.unique(np.asarray(rows, np.int64).reshape(-1))
    n = tree.n_points
    if rows.size == 0:
        raise ValueError("delete_points: empty row set")
    if rows[0] < 0 or rows[-1] >= n:
        raise ValueError(
            f"row ids must lie in [0, {n}), got range "
            f"[{rows[0]}, {rows[-1]}]")
    if rows.size >= n:
        raise ValueError(
            "cannot delete every point: the model must keep at least one")

    state = _ensure_state(vdt)
    L = tree.L
    slots = state.slot_of[rows]
    x_del = state.x_leaf[slots].copy()
    w_del = state.w_leaf[slots].copy()

    flat = _path_nodes(slots, L).ravel()
    rep = L + 1
    np.add.at(state.W, flat, np.repeat(-w_del, rep))
    np.add.at(state.S1, flat, np.repeat(-x_del * w_del[:, None], rep, axis=0))
    np.add.at(state.S2, flat, np.repeat(-(x_del * x_del).sum(-1) * w_del, rep))
    np.add.at(state.cnt, flat, -1)
    if state.sphi is not None:
        p, g, gx = _leaf_div_terms(vdt.bound_divergence.div, x_del, w_del)
        np.add.at(state.sphi, flat, np.repeat(-p, rep))
        np.add.at(state.sg, flat, np.repeat(-g, rep, axis=0))
        np.add.at(state.sgx, flat, np.repeat(-gx, rep))

    # freed slots are ghosts again (insertion headroom)
    state.x_leaf[slots] = 0.0
    state.w_leaf[slots] = 0.0

    # exact-zero emptied subtrees: integer emptiness, no float residue
    touched = np.unique(flat)
    emptied = touched[state.cnt[touched] == 0]
    state.W[emptied] = 0.0
    state.S1[emptied] = 0.0
    state.S2[emptied] = 0.0
    if state.sphi is not None:
        state.sphi[emptied] = 0.0
        state.sg[emptied] = 0.0
        state.sgx[emptied] = 0.0

    # compact row ids: survivors keep their relative order, so the row
    # ordering matches a from-scratch fit on the surviving point set
    keep = np.ones(n, bool)
    keep[rows] = False
    new_n = n - rows.size
    old_to_new = np.full(n + 1, new_n, np.int64)  # deleted + ghosts -> new_n
    old_to_new[np.flatnonzero(keep)] = np.arange(new_n)
    state.leaf_of = old_to_new[np.minimum(state.leaf_of, n)]
    state.slot_of = state.slot_of[keep]
    row_map = old_to_new[:n].copy()
    row_map[rows] = -1

    dirty_nodes = np.zeros(tree.n_nodes, bool)
    dirty_nodes[flat] = True
    return _commit(vdt, state, dirty_nodes, rows=rows, row_map=row_map,
                   new_n=new_n, patched=int(rows.size))


def _commit(vdt: VariationalDualTree, state: _StreamState,
            dirty_nodes: np.ndarray, *, rows, row_map, new_n: int,
            patched: int) -> StreamUpdate:
    """Freeze the patched mirrors into a new copy-on-write epoch."""
    old_tree = vdt.tree
    tree = PartitionTree(
        L=old_tree.L,
        n_points=new_n,
        dim=old_tree.dim,
        x_leaf=jnp.asarray(state.x_leaf, jnp.float32),
        w_leaf=jnp.asarray(state.w_leaf, jnp.float32),
        slot_of=jnp.asarray(state.slot_of, jnp.int32),
        leaf_of=jnp.asarray(state.leaf_of, jnp.int32),
        W=jnp.asarray(state.W, jnp.float32),
        S1=jnp.asarray(state.S1, jnp.float32),
        S2=jnp.asarray(state.S2, jnp.float32),
    )
    old_bound = vdt.bound_divergence
    if state.sphi is None:
        bound = div_mod.bind_divergence(old_bound.div, tree)
    else:
        stats = div_mod.DivStats(
            sphi=jnp.asarray(state.sphi, jnp.float32),
            sg=jnp.asarray(state.sg, jnp.float32),
            sgx=jnp.asarray(state.sgx, jnp.float32),
        )
        bound = div_mod.BoundDivergence(
            div=old_bound.div, stats=stats, _tree_ref=weakref.ref(tree))
        div_mod.adopt_bound(tree, bound)

    # copy-on-write partition: restore the refinement children the fit
    # dropped as all-ghost (first mutation only; later epochs are already
    # complete), then refresh coverage from the patched weights
    old_bp = vdt.bp
    bp = blocks_mod.complete_forest(old_bp)
    active = blocks_mod.refresh_active(bp, state.W)
    bp.active = active
    if bp.cap > state.d2.size:
        pad = bp.cap - state.d2.size
        state.d2 = np.concatenate([state.d2, np.zeros(pad)])
        state.stale = np.concatenate([state.stale, np.zeros(pad, bool)])

    # touched blocks: a side's stats were patched, or activation flipped
    # (slots appended by forest completion had no prior activity)
    nb = bp.n
    old_active = np.zeros(nb, bool)
    old_active[: old_bp.n] = old_bp.active[: old_bp.n]
    dirty_blk = ((dirty_nodes[bp.a[:nb]] | dirty_nodes[bp.b[:nb]]
                  | (active[:nb] != old_active))
                 & active[:nb])
    idx = np.flatnonzero(dirty_blk)
    if idx.size:
        state.d2[idx] = _block_div_np(state, bp.a[idx], bp.b[idx])

    # d-free log_g over the whole partition from the cached divergences
    wa, wb = state.W[bp.a[:nb]], state.W[bp.b[:nb]]
    ok = active[:nb] & (wa > 0) & (wb > 0)
    sig = float(vdt.sigma)
    denom = np.where(ok, 2.0 * sig * sig * wa * wb, 1.0)
    log_g = np.full(bp.cap, -np.inf, np.float32)
    log_g[:nb] = np.where(ok, -state.d2[:nb] / denom, -np.inf).astype(np.float32)
    qs = qopt_mod.optimize_q_from_g(
        tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(active),
        vdt.sigma, jnp.asarray(log_g), divergence=bound)

    # staleness: touched blocks get refinement priority on the new model
    state.stale[idx] = True
    state.stale[:nb] &= active[:nb]
    stale_blocks = int(state.stale[:nb].sum())
    state.bp_n, state.cap = bp.n, bp.cap

    new_stats = dataclasses.replace(
        vdt.stats, n_blocks=bp.n_active, bound=float(qs.bound))
    new_vdt = VariationalDualTree(
        tree=tree, bp=bp, qstate=qs, sigma=vdt.sigma, stats=new_stats,
        divergence=bound)
    state.owner = weakref.ref(new_vdt)
    new_vdt._stream = state
    return StreamUpdate(vdt=new_vdt, rows=np.asarray(rows), row_map=row_map,
                        patched_points=patched, touched_blocks=int(idx.size),
                        stale_blocks=stale_blocks)


# ============================================================== reference
def recompute(vdt: VariationalDualTree) -> VariationalDualTree:
    """Reference refit of the SAME structure: the differential oracle.

    Rebuilds every subtree statistic from the model's leaf arrays, rebinds
    the divergence stats from scratch, refreshes block activity, and runs
    the full (non-incremental) q optimization at the model's sigma over the
    same tree and block partition.  The streaming patches are exact modulo
    float accumulation order, so an incrementally mutated model must agree
    with ``recompute(model)`` to tight tolerance — that equivalence is the
    incremental-vs-refit differential test's core claim.
    """
    old = vdt.tree
    w = old.w_leaf
    W = div_mod._node_sums(w, old.L)
    S1 = div_mod._node_sums(old.x_leaf * w[:, None], old.L)
    S2 = div_mod._node_sums((old.x_leaf * old.x_leaf).sum(-1) * w, old.L)
    tree = dataclasses.replace(old, W=W, S1=S1, S2=S2)
    bound = div_mod.bind_divergence(vdt.bound_divergence.div, tree)
    old_bp = vdt.bp
    active = blocks_mod.refresh_active(old_bp, np.asarray(W))
    bp = blocks_mod.BlockPartition(
        a=old_bp.a.copy(), b=old_bp.b.copy(), mirror=old_bp.mirror.copy(),
        active=active, n=old_bp.n, cap=old_bp.cap,
        refined=old_bp.refined.copy())
    qs = qopt_mod.optimize_q(
        tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(active),
        vdt.sigma, divergence=bound)
    stats = dataclasses.replace(
        vdt.stats, n_blocks=bp.n_active, bound=float(qs.bound))
    return VariationalDualTree(tree=tree, bp=bp, qstate=qs, sigma=vdt.sigma,
                               stats=stats, divergence=bound)
