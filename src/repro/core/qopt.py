"""O(|B|) variational optimization of the block-constrained transition matrix.

Solves (paper eq. 7 subject to the row-stochasticity constraints eq. 16):

    max_q  -1/(2s^2) sum_B q_AB D2_AB  -  sum_B W_A W_B q_AB log q_AB
    s.t.   sum_{(A,B) in B(x_i)} W_B q_AB = 1   for every real leaf i

Closed-form recursion (re-derived; equivalent to Thiesson & Kim 2012, Alg. 3):

  within-node softmax:  q_AB = v_A * exp(G_AB) / z_A,
                        z_A = sum_{B in A_mkd} W_B exp(G_AB),
                        G_AB = -D2_AB / (2 s^2 W_A W_B)
  bottom-up:            Zt_leaf = z_leaf
                        Wbar_A = (W_l log Zt_l + W_r log Zt_r) / W_A
                        Zt_A   = z_A + exp(Wbar_A)
  top-down:             R_root = 1;  v_A = R_A z_A / Zt_A;  R_child = R_A - v_A
  optimum value:        l(D) = c + W_root * log Zt_root,
                        c = -W log((2 pi s^2)^{d/2} (W - 1))

Everything runs in log space over flat heap arrays; the level sweeps are
O(log N) dense steps and the block ops are segment reductions — no recursion,
no pointers.  Blocks are padded to capacity and masked with ``active``.

Bregman generalization
----------------------
Every entry point takes ``divergence=`` (``None`` | registry name |
``Divergence`` | ``BoundDivergence`` — see ``core/divergence.py``).  The
default (``None`` / ``"sqeuclidean"``) is the paper's Gaussian kernel and
stays bit-identical to the pre-Bregman implementation; any other divergence
swaps the block distance ``D2_AB`` for the block Bregman divergence
``D_AB`` (same eq.-9-style O(1) factorization) and the bound's Gaussian
log-partition constant for the divergence's own.  Out-of-domain data (e.g.
KL with non-positive coordinates) raises ``ValueError`` at bind time rather
than silently producing NaNs.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.divergence import bind_divergence
from repro.core.tree import PartitionTree

__all__ = ["QState", "block_sq_dists", "optimize_q", "optimize_q_from_g",
           "lower_bound", "block_log_G"]

_NEG_INF = -jnp.inf


class QState(NamedTuple):
    """Result of one q-optimization."""

    log_q: jax.Array    # (cap,)      log q_AB (−inf where inactive)
    log_v: jax.Array    # (n_nodes,)  per-node allocated mass (log)
    log_z: jax.Array    # (n_nodes,)  per-node mark partition function (log)
    log_zt: jax.Array   # (n_nodes,)  per-node subtree partition function (log)
    bound: jax.Array    # ()          variational lower bound l(D)


def block_sq_dists(tree: PartitionTree, a: jax.Array, b: jax.Array,
                   divergence=None) -> jax.Array:
    """Block divergence D_AB from subtree statistics, O(1) per block.

    For the default Gaussian kernel this is D2_AB of paper eq. 9 (the name
    is kept for API stability); for any other registered divergence it is
    the block Bregman divergence via the generalized factorization in
    ``core/divergence.py``.
    """
    return bind_divergence(divergence, tree).block_div(tree, a, b)


def block_log_G(tree: PartitionTree, a: jax.Array, b: jax.Array,
                active: jax.Array, sigma: jax.Array,
                divergence=None) -> jax.Array:
    """G_AB = -D_AB/(2 s^2 W_A W_B); −inf on inactive/ghost blocks."""
    wa, wb = tree.W[a], tree.W[b]
    ok = active & (wa > 0) & (wb > 0)
    denom = jnp.where(ok, 2.0 * sigma * sigma * wa * wb, 1.0)
    g = -block_sq_dists(tree, a, b, divergence=divergence) / denom
    return jnp.where(ok, g, _NEG_INF)


def _segment_logsumexp(logits: jax.Array, segment_ids: jax.Array,
                       num_segments: int) -> jax.Array:
    """Numerically stable segmented logsumexp; −inf for empty segments."""
    m = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    shifted = jnp.where(jnp.isfinite(logits), jnp.exp(logits - m_safe[segment_ids]), 0.0)
    s = jax.ops.segment_sum(shifted, segment_ids, num_segments=num_segments)
    return jnp.where(s > 0, jnp.log(jnp.maximum(s, 1e-38)) + m_safe, _NEG_INF)


@functools.partial(jax.jit, static_argnames=("L",))
def _optimize_impl(W, log_z, log_part, L: int):
    n_nodes = W.shape[0]

    # ---- bottom-up: log Zt and Wbar --------------------------------------
    log_zt = log_z
    wbar = jnp.full((n_nodes,), _NEG_INF, dtype=log_z.dtype)
    for lvl in range(L - 1, -1, -1):
        lo, hi = (1 << lvl) - 1, (1 << (lvl + 1)) - 1
        clo, chi = hi, (1 << (lvl + 2)) - 1
        zc = jax.lax.dynamic_slice_in_dim(log_zt, clo, chi - clo)
        wc = jax.lax.dynamic_slice_in_dim(W, clo, chi - clo)
        zl, zr = zc[0::2], zc[1::2]
        wl, wr = wc[0::2], wc[1::2]
        wn = jax.lax.dynamic_slice_in_dim(W, lo, hi - lo)
        # weighted geometric mean in log space; 0-weight children contribute 0,
        # a positive-weight child with no marks anywhere below forces −inf
        # (all its row mass must be consumed at or above this node).
        num = (jnp.where(wl > 0, wl * zl, 0.0) + jnp.where(wr > 0, wr * zr, 0.0))
        any_neg_inf = ((wl > 0) & ~jnp.isfinite(zl)) | ((wr > 0) & ~jnp.isfinite(zr))
        wb_lvl = jnp.where(
            (wn > 0) & ~any_neg_inf, num / jnp.maximum(wn, 1e-12), _NEG_INF
        )
        zn = jax.lax.dynamic_slice_in_dim(log_z, lo, hi - lo)
        zt_lvl = jnp.logaddexp(zn, wb_lvl)
        log_zt = jax.lax.dynamic_update_slice_in_dim(log_zt, zt_lvl, lo, axis=0)
        wbar = jax.lax.dynamic_update_slice_in_dim(wbar, wb_lvl, lo, axis=0)

    # ---- top-down: remaining mass R and per-node mass v ------------------
    log_r = jnp.full((n_nodes,), _NEG_INF, dtype=log_z.dtype)
    log_r = log_r.at[0].set(0.0)
    for lvl in range(0, L):
        lo, hi = (1 << lvl) - 1, (1 << (lvl + 1)) - 1
        rn = jax.lax.dynamic_slice_in_dim(log_r, lo, hi - lo)
        wb_lvl = jax.lax.dynamic_slice_in_dim(wbar, lo, hi - lo)
        zt_lvl = jax.lax.dynamic_slice_in_dim(log_zt, lo, hi - lo)
        # log R_child = log R + Wbar − log Zt   (R_child = R · e^Wbar / Zt)
        rc = jnp.where(jnp.isfinite(rn) & jnp.isfinite(wb_lvl), rn + wb_lvl - zt_lvl,
                       _NEG_INF)
        rc2 = jnp.repeat(rc, 2)
        log_r = jax.lax.dynamic_update_slice_in_dim(log_r, rc2, hi, axis=0)

    log_v = jnp.where(
        jnp.isfinite(log_r) & jnp.isfinite(log_z), log_r + log_z - log_zt, _NEG_INF
    )

    # ---- bound ------------------------------------------------------------
    w_tot = W[0]
    const = -w_tot * (log_part + jnp.log(jnp.maximum(w_tot - 1.0, 1.0)))
    bound = const + w_tot * log_zt[0]
    return log_v, log_zt, bound


def optimize_q_from_g(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    sigma: jax.Array,
    log_g: jax.Array,
    divergence=None,
) -> QState:
    """Optimal q given precomputed block log-similarities ``log_g``.

    The d-free tail of :func:`optimize_q`: everything past ``log_g`` is
    O(|B| + N) segment/level sweeps with no dependence on the data
    dimension.  The streaming layer (``core/streaming.py``) exploits this —
    after an insert/delete it recomputes block divergences only for the
    touched blocks on the host, derives ``log_g`` for the full partition,
    and re-optimizes globally through this entry point, so the expensive
    O(|B| d) ``block_log_G`` pass is skipped entirely.  ``divergence`` is
    only consulted for the bound's log-partition constant.
    """
    n_nodes = tree.n_nodes
    div = bind_divergence(divergence, tree)
    wb = tree.W[b]
    contrib = jnp.where(
        active & (wb > 0), jnp.log(jnp.maximum(wb, 1e-12)) + log_g, _NEG_INF
    )
    log_z = _segment_logsumexp(contrib, a, n_nodes)
    log_part = div.log_partition(jnp.asarray(tree.dim, jnp.float32), sigma)
    log_v, log_zt, bound = _optimize_impl(tree.W, log_z, log_part, tree.L)
    log_q = jnp.where(
        jnp.isfinite(log_g) & jnp.isfinite(log_v[a]),
        log_v[a] + log_g - log_z[a],
        _NEG_INF,
    )
    return QState(log_q=log_q, log_v=log_v, log_z=log_z, log_zt=log_zt, bound=bound)


def optimize_q(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    sigma: jax.Array,
    divergence=None,
) -> QState:
    """Optimal block parameters q for the given partition and bandwidth."""
    div = bind_divergence(divergence, tree)
    log_g = block_log_G(tree, a, b, active, sigma, divergence=div)
    return optimize_q_from_g(tree, a, b, active, sigma, log_g, divergence=div)


def lower_bound(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    sigma: jax.Array,
    divergence=None,
) -> jax.Array:
    """l(D) (eq. 7) for *arbitrary* feasible q — used by tests/refinement.

    With a non-default ``divergence`` the distance term uses the block
    Bregman divergence and the constant uses that divergence's log-partition
    term; a divergence/domain mismatch (e.g. KL over a tree fitted on
    non-positive data) raises ``ValueError`` instead of returning NaN.
    """
    div = bind_divergence(divergence, tree)
    wa, wb = tree.W[a], tree.W[b]
    ok = active & (wa > 0) & (wb > 0) & jnp.isfinite(log_q)
    q = jnp.where(ok, jnp.exp(log_q), 0.0)
    d2 = div.block_div(tree, a, b)
    dist_term = -jnp.where(ok, q * d2, 0.0).sum() / (2.0 * sigma * sigma)
    ent_term = -jnp.where(ok, wa * wb * q * log_q, 0.0).sum()
    w_tot = tree.W[0]
    const = -w_tot * (
        div.log_partition(tree.dim, sigma)
        + jnp.log(jnp.maximum(w_tot - 1.0, 1.0))
    )
    return const + dist_term + ent_term
