"""Public API: the Variational Dual-Tree transition-matrix approximation.

    vdt = VariationalDualTree.fit(x, max_blocks=4 * n)
    y_hat = vdt.matvec(y)                   # O(|B|) Q @ y
    y_lp  = vdt.label_propagate(y0)         # label propagation (eq. 15)
    q     = vdt.dense_q()                   # small-N debugging / tests

Pipeline (paper §3-§4): build the shared partition tree -> coarsest block
partition (|B| = 2(Np-1)) -> alternate q-optimization (eq. 7) with bandwidth
learning (eq. 12) -> greedy symmetric refinement to the block budget
(eq. 19) -> O(|B|) inference (Algorithm 1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import divergence as div_mod
from repro.core import matvec as matvec_mod
from repro.core import qopt as qopt_mod
from repro.core import refine as refine_mod
from repro.core import sigma as sigma_mod
from repro.core.label_prop import (lp_scan_fused, lp_scan_fused_resume,
                                   lp_scan_leaforder, lp_scan_leaforder_resume)
from repro.core.tree import PartitionTree, build_tree

__all__ = ["VariationalDualTree", "VdtStats"]


@dataclasses.dataclass
class VdtStats:
    build_tree_s: float = 0.0
    init_qopt_s: float = 0.0
    refine_s: float = 0.0
    sigma_iters: int = 0
    n_blocks: int = 0
    bound: float = 0.0
    sigma: float = 0.0
    divergence: str = "sqeuclidean"


@dataclasses.dataclass
class VariationalDualTree:
    tree: PartitionTree
    bp: blocks_mod.BlockPartition
    qstate: qopt_mod.QState
    sigma: jax.Array
    stats: VdtStats
    # the Bregman divergence this model was fitted under, bound to `tree`
    # (block-stats precomputed); None means the default Gaussian kernel and
    # is lazily normalized to the bound sqeuclidean divergence
    divergence: Optional[div_mod.BoundDivergence] = None
    # device-resident dispatch buffers (a, b, active, q, leaf_mask), built
    # lazily and reused across serving calls / scheduler iterations; q never
    # changes between refinements so re-deriving it per call is pure waste.
    _serve_cache: Optional[tuple] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    # points in original row order (exact-backend LP reads them); derived
    # from the tree's leaf-order copy once and reused
    _x_rows_cache: Optional[jax.Array] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    # mutable float64 host mirrors for streaming insert/delete
    # (core/streaming.py); rides on the newest epoch only and is rebuilt
    # transparently when absent or stale
    _stream: Optional[object] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    # CSR transition graph over the fitted points for the GRF backend
    # (core/grf.py), built from the dense eq.-3 kernel once and cached —
    # epochs are copy-on-write, so the cache is stable for this model's
    # lifetime and every dispatch against it walks identical bits
    _grf_cache: Optional[object] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    # ------------------------------------------------------------------ fit
    @classmethod
    def fit(
        cls,
        x,
        weights=None,
        max_blocks: Optional[int] = None,
        sigma: Optional[float] = None,
        learn_sigma: bool = True,
        refine_batch: int = 64,
        sigma_iters: int = 10,
        power_iters: int = 8,
        divergence="sqeuclidean",
        capacity: Optional[int] = None,
    ) -> "VariationalDualTree":
        """Build tree + coarsest partition, fit sigma/q, refine to budget.

        ``divergence`` selects the Bregman divergence the similarity kernel
        ``exp(-d(x_i, x_j) / 2 s^2)`` is built from — a registry name
        (``"sqeuclidean"`` default, ``"kl"``, ``"itakura_saito"``,
        ``"mahalanobis"``) or a :class:`~repro.core.divergence.Divergence`.
        Positive-domain divergences (KL, Itakura-Saito) validate ``x`` up
        front and raise ``ValueError`` on out-of-domain data.  ``sigma``
        keeps its role as the kernel temperature; ``sigma_init`` stays the
        Gaussian moment heuristic, which is only a starting scale for the
        eq.-12 alternation.

        ``capacity`` (>= N) reserves ghost leaf headroom for streaming
        inserts (:meth:`insert_points`); without it the tree only has the
        power-of-two rounding slack.
        """
        div = div_mod.resolve_divergence(divergence)
        div.validate_domain(x)  # fail fast, before any device work
        stats = VdtStats(divergence=div.name)
        x = jnp.asarray(x, jnp.float32)

        t0 = time.perf_counter()
        tree = build_tree(x, weights, power_iters=power_iters,
                          capacity=capacity)
        jax.block_until_ready(tree.W)
        stats.build_tree_s = time.perf_counter() - t0
        # bind via the memo so later public-API calls with the name form
        # reuse these stats instead of recomputing the O(N d) pass
        bound_div = div_mod.bind_divergence(div, tree)

        cap = max_blocks if max_blocks else 2 * tree.n_internal
        bp = blocks_mod.coarsest_partition(tree, cap=int(2.5 * cap))

        t0 = time.perf_counter()
        sig = jnp.asarray(
            sigma if sigma is not None else sigma_mod.sigma_init(x, weights),
            jnp.float32,
        )
        if learn_sigma and sigma is None:
            sig, qs, its = sigma_mod.fit_sigma_q(
                tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active),
                sig, max_iters=sigma_iters, divergence=bound_div,
            )
            stats.sigma_iters = its
        else:
            qs = qopt_mod.optimize_q(
                tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active),
                sig, divergence=bound_div,
            )
        jax.block_until_ready(qs.log_q)
        stats.init_qopt_s = time.perf_counter() - t0

        if max_blocks and max_blocks > bp.n_active:
            t0 = time.perf_counter()
            qs, sig = refine_mod.refine_to_budget(
                bp, tree, sig, max_blocks, batch=refine_batch,
                refit_sigma=learn_sigma, divergence=bound_div,
            )
            jax.block_until_ready(qs.log_q)
            stats.refine_s = time.perf_counter() - t0

        stats.n_blocks = bp.n_active
        stats.bound = float(qs.bound)
        stats.sigma = float(sig)
        return cls(tree=tree, bp=bp, qstate=qs, sigma=sig, stats=stats,
                   divergence=bound_div)

    # ------------------------------------------------------------- inference
    @property
    def bound_divergence(self) -> div_mod.BoundDivergence:
        """The fitted divergence, normalized (``None`` -> bound sqeuclidean)."""
        if self.divergence is None:
            self.divergence = div_mod.bind_divergence(None, self.tree)
        return self.divergence

    @property
    def divergence_name(self) -> str:
        """Registry name of the fitted divergence (serving dispatch keys)."""
        return self.bound_divergence.name

    def _dispatch_buffers(self) -> tuple:
        """(a, b, active, q, leaf_mask) on device, cached across calls.

        ``leaf_mask`` is 1.0 exactly at leaf slots holding a real row (so the
        leaf-order LP scan can keep ghost slots at zero); ``q`` is the
        ready-to-use ``exp(log_q)`` from :func:`~repro.core.matvec.prepare_q`.
        Invalidated by :meth:`refine`.
        """
        if self._serve_cache is None:
            a = jnp.asarray(self.bp.a)
            b = jnp.asarray(self.bp.b)
            active = jnp.asarray(self.bp.active)
            q = matvec_mod.prepare_q(active, self.qstate.log_q)
            mask = jnp.zeros((self.tree.n_leaves, 1), jnp.float32)
            mask = mask.at[self.tree.slot_of, 0].set(1.0)
            jax.block_until_ready(q)
            self._serve_cache = (a, b, active, q, mask)
        return self._serve_cache

    @property
    def x_rows(self) -> jax.Array:
        """The fitted points in original row order, (N, d), cached on device."""
        if self._x_rows_cache is None:
            self._x_rows_cache = self.tree.x_leaf[self.tree.slot_of]
        return self._x_rows_cache

    def matvec(self, y) -> jax.Array:
        """Q @ y in O(|B| + N) (Algorithm 1).

        Accepts a single RHS ``(N,)``/``(N, C)`` or a stacked multi-RHS
        ``(batch, N, C)``; the latter is served in ONE device dispatch via
        the channel-folded batched path (see ``core.matvec``).
        """
        a, b, active, _, _ = self._dispatch_buffers()
        return matvec_mod.mpt_matvec(
            self.tree, a, b, active, self.qstate.log_q, y,
        )

    def matvec_batched(self, ys) -> jax.Array:
        """Explicit batched multi-RHS: (batch, N, C) -> (batch, N, C)."""
        a, b, active, _, _ = self._dispatch_buffers()
        return matvec_mod.mpt_matvec_batched(
            self.tree, a, b, active, self.qstate.log_q, ys,
        )

    def grf_graph(self):
        """The CSR transition graph the GRF backend walks, cached.

        Bridged from the fitted point cloud via the dense eq.-3 kernel
        (``core.grf.CSRGraph.from_points``), so GRF estimates are unbiased
        for exactly the matrix the ``"exact"`` backend serves.  Raises
        ``ValueError`` for positive-domain divergences (KL,
        Itakura-Saito) — see ``core/grf.py``.
        """
        from repro.core import grf as grf_mod

        if self._grf_cache is None:
            self._grf_cache = grf_mod.CSRGraph.from_points(
                self.x_rows, float(self.sigma),
                divergence=self.bound_divergence.div)
        return self._grf_cache

    def label_propagate(self, y0, alpha=0.01, n_iters: int = 500,
                        batched: Optional[bool] = None,
                        backend: str = "vdt",
                        n_walkers: Optional[int] = None, seed: int = 0):
        """Label propagation (eq. 15) from seed labels ``y0``.

        ``y0`` may be a single ``(N, C)`` label matrix or a stacked
        ``(batch, N, C)`` set of independent propagation problems over the
        same fitted tree.  ``batched=None`` infers from ``y0.ndim``; the
        batched path folds the batch into the channel axis once, runs the
        whole ``lax.scan`` in the folded ``(N, batch * C)`` layout (so every
        iteration is a single Algorithm-1 dispatch), and unfolds at the end.

        ``alpha`` may be a scalar, a per-column ``(C,)`` array (2-D ``y0``),
        or a per-request ``(batch,)`` array (3-D ``y0``) — LP is
        column-independent, so heterogeneous alphas are exact and share the
        one dispatch.  Alpha is a *traced* argument of the underlying jitted
        scan: serving different alphas never grows the compile cache.

        ``backend`` selects the transition matrix the walk runs on:

        * ``"vdt"`` (default) — the fitted O(|B|) approximation Q.  The scan
          runs in leaf order end-to-end (``lp_scan_leaforder``): the
          row<->leaf permutation costs one scatter + one gather per *call*
          instead of per iteration, and the jitted executable is cached per
          ``(n_iters, shape)`` so steady-state serving pays dispatch only.
        * ``"exact"`` — the exact eq.-3 matrix P, streamed through the
          distance-reusing fused Pallas kernel (``lp_scan_fused``): P is
          never materialized, and a batched stack pays the
          pairwise-distance/softmax work once per iteration for ALL
          requests.  O(N^2 d) per iteration — the accuracy-validation path,
          not the large-N serving path.
        * ``"grf"`` — the graph-random-features walker estimator
          (``core.grf.grf_label_propagate``) over the cached
          :meth:`grf_graph`: an unbiased Monte-Carlo estimate of the same
          eq.-15 walk, O(N * n_walkers) per iteration.  ``n_walkers``
          (default ``core.grf.DEFAULT_N_WALKERS``) is the accuracy dial —
          relative error ~ ``1/sqrt(n_walkers)`` — and ``seed`` makes the
          estimate deterministic (bit-identical per ``(seed, shapes)``).
          Both are ignored by the other backends.
        """
        y0 = jnp.asarray(y0)
        if not jnp.issubdtype(y0.dtype, jnp.floating):
            y0 = y0.astype(jnp.float32)
        if backend not in ("vdt", "exact", "grf"):
            raise ValueError(
                f"backend must be 'vdt', 'exact' or 'grf', got {backend!r}")
        if backend == "grf":
            from repro.core import grf as grf_mod

            if batched and y0.ndim != 3:
                raise ValueError(
                    f"batched label_propagate wants (batch, N, C), got {y0.shape}")
            return grf_mod.grf_label_propagate(
                self.grf_graph(), y0, alpha=alpha, n_iters=int(n_iters),
                n_walkers=int(n_walkers or grf_mod.DEFAULT_N_WALKERS),
                seed=int(seed))
        if backend == "exact":
            if batched and y0.ndim != 3:
                raise ValueError(
                    f"batched label_propagate wants (batch, N, C), got {y0.shape}")
            return lp_scan_fused(self.x_rows, y0, float(self.sigma), alpha,
                                 int(n_iters),
                                 divergence=self.bound_divergence.div)
        if batched is None:
            batched = y0.ndim == 3
        if batched:
            if y0.ndim != 3:
                raise ValueError(
                    f"batched label_propagate wants (batch, N, C), got {y0.shape}")
            batch, _, c = y0.shape
            alpha = jnp.asarray(alpha, y0.dtype)
            if alpha.ndim == 1:
                if alpha.shape[0] != batch:
                    raise ValueError(
                        f"per-request alpha wants shape ({batch},), got {alpha.shape}")
                # folded column b*C + ch belongs to request b (see fold_batch)
                alpha = jnp.repeat(alpha, c)
            out = self.label_propagate(matvec_mod.fold_batch(y0), alpha=alpha,
                                       n_iters=n_iters, batched=False)
            return matvec_mod.unfold_batch(out, batch, c)

        squeeze = y0.ndim == 1
        if squeeze:
            y0 = y0[:, None]
        tree = self.tree
        a, b, _, q, mask = self._dispatch_buffers()
        y_leaf = jnp.zeros((tree.n_leaves, y0.shape[1]), y0.dtype)
        y_leaf = y_leaf.at[tree.slot_of].set(y0)
        out_leaf = lp_scan_leaforder(
            y_leaf, mask, a, b, q, jnp.asarray(alpha, y0.dtype),
            tree.L, int(n_iters),
        )
        out = out_leaf[tree.slot_of]
        return out[:, 0] if squeeze else out

    def label_propagate_resume(self, y, y0, alpha=0.01, n_iters: int = 500,
                               batched: Optional[bool] = None,
                               backend: str = "vdt"):
        """Continue an eq.-15 walk for ``n_iters`` more steps from carry ``y``.

        The segmented-dispatch counterpart of :meth:`label_propagate`: ``y``
        is the output of an earlier (shorter) propagation from the same seed
        ``y0``, and the continued walk is *bit-identical* to having run the
        combined iteration count monolithically — eq. 15 is a pure
        fixed-point iteration, so the split is exact (see
        ``core.label_prop.lp_scan_leaforder_resume`` /
        ``lp_scan_fused_resume``).  The serving engine calls this once per
        checkpointed segment, re-checking its queue between calls so a
        tight-deadline arrival can preempt a long in-flight dispatch.

        Shapes, ``alpha`` semantics, and ``backend`` match
        :meth:`label_propagate`; ``y`` must have ``y0``'s exact shape.
        """
        y0 = jnp.asarray(y0)
        if not jnp.issubdtype(y0.dtype, jnp.floating):
            y0 = y0.astype(jnp.float32)
        y = jnp.asarray(y, y0.dtype)
        if y.shape != y0.shape:
            raise ValueError(
                f"carry shape {y.shape} must match seed shape {y0.shape}")
        if backend == "grf":
            # the MC estimator is a weighted sum over walk prefixes, not a
            # fixed-point iteration: a carry is not its complete state, so
            # there is no exact resume primitive — grf dispatches are
            # always monolithic (the serving engine never segments them)
            raise ValueError(
                "backend='grf' does not support segmented resume; "
                "grf scans dispatch monolithically")
        if backend not in ("vdt", "exact"):
            raise ValueError(
                f"backend must be 'vdt' or 'exact', got {backend!r}")
        if backend == "exact":
            if batched and y0.ndim != 3:
                raise ValueError(
                    f"batched label_propagate wants (batch, N, C), got {y0.shape}")
            return lp_scan_fused_resume(
                self.x_rows, y, y0, float(self.sigma), alpha, int(n_iters),
                divergence=self.bound_divergence.div)
        if batched is None:
            batched = y0.ndim == 3
        if batched:
            if y0.ndim != 3:
                raise ValueError(
                    f"batched label_propagate wants (batch, N, C), got {y0.shape}")
            batch, _, c = y0.shape
            alpha = jnp.asarray(alpha, y0.dtype)
            if alpha.ndim == 1:
                if alpha.shape[0] != batch:
                    raise ValueError(
                        f"per-request alpha wants shape ({batch},), got {alpha.shape}")
                alpha = jnp.repeat(alpha, c)
            out = self.label_propagate_resume(
                matvec_mod.fold_batch(y), matvec_mod.fold_batch(y0),
                alpha=alpha, n_iters=n_iters, batched=False)
            return matvec_mod.unfold_batch(out, batch, c)

        squeeze = y0.ndim == 1
        if squeeze:
            y, y0 = y[:, None], y0[:, None]
        tree = self.tree
        a, b, _, q, mask = self._dispatch_buffers()
        # ghost slots are zero both in the seed and (by the re-masking
        # invariant) in any mid-walk carry, so scattering the row-order
        # carry into zeros reproduces the in-scan leaf state exactly
        y0_leaf = jnp.zeros((tree.n_leaves, y0.shape[1]), y0.dtype)
        y0_leaf = y0_leaf.at[tree.slot_of].set(y0)
        y_leaf = jnp.zeros((tree.n_leaves, y0.shape[1]), y0.dtype)
        y_leaf = y_leaf.at[tree.slot_of].set(y)
        out_leaf = lp_scan_leaforder_resume(
            y_leaf, y0_leaf, mask, a, b, q, jnp.asarray(alpha, y0.dtype),
            tree.L, int(n_iters),
        )
        out = out_leaf[tree.slot_of]
        return out[:, 0] if squeeze else out

    # ------------------------------------------------------------- streaming
    def insert_points(self, x_new, weights=None):
        """Insert points online; returns a StreamUpdate with the new epoch.

        O(k d log N) stat patching, no refit — see ``core/streaming.py``.
        Copy-on-write: ``self`` is untouched; serve from ``update.vdt``.
        """
        from repro.core.streaming import insert_points as _ins
        return _ins(self, x_new, weights=weights)

    def delete_points(self, rows):
        """Delete points by row id online; see :meth:`insert_points`."""
        from repro.core.streaming import delete_points as _del
        return _del(self, rows)

    # ------------------------------------------------------------- utilities
    def refine(self, max_blocks: int, batch: int = 64) -> None:
        stream = self._stream
        stale = None
        if stream is not None and stream.owner() is self:
            # streaming-touched blocks get the budget first
            stale = stream.stale
        self.qstate, self.sigma = refine_mod.refine_to_budget(
            self.bp, self.tree, self.sigma, max_blocks, batch=batch,
            divergence=self.bound_divergence, stale=stale,
        )
        self._serve_cache = None  # a/b/q/active all changed
        self._stream = None  # refinement regrew the partition; mirrors stale
        self.stats.n_blocks = self.bp.n_active
        self.stats.bound = float(self.qstate.bound)

    def _check_finite_q(self) -> None:
        """Guard against a divergence/domain mismatch poisoning the model.

        ``fit`` validates the data domain up front, but a hand-constructed
        model (or one whose q-state was recomputed under the wrong
        divergence) can carry NaN/-inf-everywhere q; surface that as a clear
        error instead of silently emitting NaN results downstream.
        """
        bound = np.asarray(self.qstate.bound)
        if not np.isfinite(bound):
            raise ValueError(
                f"non-finite variational state (bound={float(bound)}) under "
                f"divergence {self.divergence_name!r} — likely a "
                f"divergence/domain mismatch (e.g. 'kl' requires strictly "
                f"positive inputs); refit with in-domain data or the "
                f"right divergence")

    def dense_q(self) -> np.ndarray:
        """Dense (N, N) Q — small-N tests only."""
        self._check_finite_q()
        q = np.asarray(
            jnp.where(jnp.isfinite(self.qstate.log_q), jnp.exp(self.qstate.log_q), 0.0)
        )
        return blocks_mod.densify_q(self.bp, self.tree, q)

    def lower_bound(self, log_q=None) -> jax.Array:
        """l(D) for ``log_q`` (default: the fitted q) under the fitted divergence."""
        self._check_finite_q()
        a, b, active, _, _ = self._dispatch_buffers()
        lq = self.qstate.log_q if log_q is None else jnp.asarray(log_q)
        return qopt_mod.lower_bound(self.tree, a, b, active, lq, self.sigma,
                                    divergence=self.bound_divergence)

    @property
    def n_blocks(self) -> int:
        return self.bp.n_active

    @property
    def bound(self) -> float:
        return float(self.qstate.bound)
