"""Pluggable Bregman divergences for the VDT core (Bregman VDT, arXiv:1309.6812).

The source paper's variational machinery (eqs. 3/13/15) only ever touches the
data through pairwise *squared Euclidean* distances, aggregated per block via
the subtree-statistics factorization (eq. 9).  The follow-up Bregman VDT
framework observes that the same block-partition optimization goes through for
any Bregman divergence

    d_phi(a, b) = phi(a) - phi(b) - <grad phi(b), a - b>

because the block-level sum factorizes just like eq. 9:

    D_AB = sum_{i in A, j in B} w_i w_j d_phi(x_i, x_j)
         = W_B * Sphi_A  -  W_A * Sphi_B  -  <S1_A, Sg_B>  +  W_A * Sgx_B

with per-node sums ``Sphi = sum_i w_i phi(x_i)``, ``Sg = sum_i w_i grad
phi(x_i)``, ``Sgx = sum_i w_i <grad phi(x_i), x_i>`` (``W``/``S1`` are the
tree's existing stats).  One O(N d) bottom-up pass yields O(1)-per-block
divergences — exactly the property the Gaussian core was built on.

This module is the single registry the rest of the stack consumes:

* ``core/qopt.py`` — ``block_sq_dists``/``block_log_G``/``optimize_q``/
  ``lower_bound`` take ``divergence=`` and stay bit-exact for the default;
* ``core/vdt.py`` — ``VariationalDualTree.fit(divergence=...)``;
* ``kernels/fused_lp`` — the streaming kernels compute the divergence tile
  via :meth:`Divergence.tile` (pure jnp, Pallas-traceable) instead of the
  hard-coded ``||a-b||^2``;
* ``serving/engine.py`` — the divergence name rides in the dispatch key so
  mixed-divergence engines never share a compiled executable.

Registered divergences
----------------------
``sqeuclidean``     phi(x) = ||x||^2            (the paper's Gaussian kernel)
``kl``              phi(x) = sum x log x        (generalized KL; x > 0)
``itakura_saito``   phi(x) = -sum log x         (spectral/count data; x > 0)
``mahalanobis``     phi(x) = sum m_k x_k^2      (diagonal metric; see
                                                 :func:`mahalanobis`)

``sqeuclidean`` is special-cased everywhere to the pre-existing formulas so
the default path is bit-identical to the Gaussian-only implementation
(pinned by ``tests/test_divergence.py`` against a committed golden fixture).
"""
from __future__ import annotations

import dataclasses
import hashlib
import weakref
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import PartitionTree

__all__ = [
    "DIVERGENCES",
    "BoundDivergence",
    "DivStats",
    "Divergence",
    "adopt_bound",
    "bind_divergence",
    "get_divergence",
    "mahalanobis",
    "register_divergence",
    "resolve_divergence",
]


class DivStats(NamedTuple):
    """Per-node Bregman sufficient statistics, heap-indexed like ``tree.W``."""

    sphi: jax.Array  # (n_nodes,)    sum_i w_i phi(x_i)
    sg: jax.Array    # (n_nodes, d)  sum_i w_i grad phi(x_i)
    sgx: jax.Array   # (n_nodes,)    sum_i w_i <grad phi(x_i), x_i>


def _node_sums(leaf_vals: jax.Array, L: int) -> jax.Array:
    """Bottom-up subtree sums, level-major then flat-concatenated.

    Same aggregation pattern as ``tree._build_impl``: leaves at level L, each
    internal level the pairwise sum of its children, concatenated root-first
    into the flat heap order every block op indexes into.
    """
    vals = [leaf_vals]
    for _ in range(L):
        vals.append(vals[-1].reshape((-1, 2) + vals[-1].shape[1:]).sum(1))
    return jnp.concatenate(vals[::-1])


@dataclasses.dataclass(frozen=True, eq=False)
class Divergence:
    """One Bregman divergence: generator, block stats, kernel tile, domain.

    Instances are immutable and hash/compare **by name**, so a
    ``Divergence`` (or its ``name``) can ride as a *static* jit argument —
    that is how the fused kernels keep one compiled executable per
    divergence without ever cross-contaminating the cache.  Name-keyed
    equality matters for parameterized factories: two ``mahalanobis(scale)``
    calls with the same scale yield fresh closure objects but the same
    digest-embedding name, and MUST share a compiled executable rather than
    retrace per instance.

    ``_pairwise`` is implemented per-divergence (rather than derived from
    ``phi``/``grad_phi``) so each uses its numerically best matmul form; it
    doubles as the Pallas tile function via :meth:`tile`.
    """

    name: str
    _phi: Callable[[jax.Array], jax.Array]
    _grad_phi: Callable[[jax.Array], jax.Array]
    _pairwise: Callable[[jax.Array, jax.Array], jax.Array]
    _log_partition: Callable[..., jax.Array]
    # value padded rows/ghosts are substituted with so domain functions stay
    # finite (1.0 for positive-domain divergences, 0.0 otherwise); masked
    # out of every real result downstream
    pad_value: float = 0.0
    positive_domain: bool = False
    # optional point pre-map under which the divergence IS squared Euclidean
    # (e.g. Mahalanobis: x -> x * sqrt(m)).  Kernels apply it OUTSIDE the
    # Pallas body and keep the inline distance tile, so tile functions never
    # capture array constants (which Pallas kernels cannot close over).
    _transform: Optional[Callable[[jax.Array], jax.Array]] = None
    # required trailing data dimension (parameterized metrics whose scale
    # vector must match d); None = any dimension
    required_dim: Optional[int] = None

    # name IS the identity: factories embed a digest of their parameters in
    # it, so equal names imply equal behavior (and jit static-arg keys dedup)
    def __eq__(self, other) -> bool:
        return isinstance(other, Divergence) and other.name == self.name

    def __hash__(self) -> int:
        return hash((Divergence, self.name))

    # ------------------------------------------------------------ pointwise
    def phi(self, x: jax.Array) -> jax.Array:
        """Generator phi, (…, d) -> (…)."""
        return self._phi(x)

    def grad_phi(self, x: jax.Array) -> jax.Array:
        """Gradient of phi, (…, d) -> (…, d)."""
        return self._grad_phi(x)

    def pairwise(self, xa: jax.Array, xb: jax.Array) -> jax.Array:
        """Dense divergence matrix d_phi(xa_i, xb_j), (m, d), (n, d) -> (m, n)."""
        return jnp.maximum(self._pairwise(xa, xb), 0.0)

    def tile(self, rows: jax.Array, cols: jax.Array) -> jax.Array:
        """Kernel tile form of :meth:`pairwise` (f32 in, f32 out).

        Pure jnp with MXU-friendly matmuls and no array-valued closure
        constants, so Pallas traces it inside the streaming kernels exactly
        like the built-in distance tile.
        """
        return jnp.maximum(
            self._pairwise(rows.astype(jnp.float32), cols.astype(jnp.float32)),
            0.0,
        )

    def transform_points(self, x: jax.Array) -> jax.Array:
        """Point pre-map under which the divergence is squared Euclidean.

        Identity for most divergences; kernels call it outside the Pallas
        body (see ``kernels.fused_lp.fused_lp.tile_config``).
        """
        return x if self._transform is None else self._transform(x)

    @property
    def euclidean_after_transform(self) -> bool:
        """True when the kernel should use the inline ``||a-b||^2`` tile on
        :meth:`transform_points`-mapped points instead of :meth:`tile`."""
        return self.name == "sqeuclidean" or self._transform is not None

    # --------------------------------------------------------------- domain
    def validate_domain(self, x) -> None:
        """Raise ``ValueError`` when ``x`` lies outside phi's domain.

        Checks the trailing dimension for parameterized metrics too, so a
        scale/data mismatch fails here with a clear message instead of as an
        opaque broadcast error deep inside jit.
        """
        arr = np.asarray(x)
        if (self.required_dim is not None and arr.ndim
                and arr.shape[-1] != self.required_dim):
            raise ValueError(
                f"divergence {self.name!r} is parameterized for "
                f"{self.required_dim}-dimensional points, got d={arr.shape[-1]}")
        if not self.positive_domain:
            return
        lo = float(np.min(arr)) if arr.size else 1.0
        if not np.isfinite(lo) or lo <= 0.0:
            raise ValueError(
                f"divergence {self.name!r} requires strictly positive inputs; "
                f"got min={lo:g}. Shift/clip the data onto the positive "
                f"orthant or use divergence='sqeuclidean'.")

    def log_partition(self, dim, sigma) -> jax.Array:
        """Log-partition term of the similarity kernel ``exp(-D/(2 s^2))``.

        For ``sqeuclidean``/``mahalanobis`` this is the exact (anisotropic)
        Gaussian normalizer the paper's bound constant uses.  KL and
        Itakura-Saito have no closed-form normalizer over their domain; they
        use the same ``d/2 log(2 pi s^2)`` functional form as a *surrogate*
        base measure, which keeps the eq.-12 bandwidth update the exact
        stationary point of the (surrogate) bound — so ``fit_sigma_q``
        remains coordinate ascent — while the bound itself is defined up to
        the intractable base-measure constant (q-optimization and refinement
        are unaffected by constants).
        """
        return self._log_partition(dim, sigma)

    # ----------------------------------------------------------------- bind
    def bind(self, tree: PartitionTree) -> "BoundDivergence":
        """Precompute the per-node Bregman stats for ``tree``.

        Validates the (real) leaf data against phi's domain first, so a
        KL/Itakura-Saito fit over out-of-domain data fails here with a clear
        error instead of silently propagating NaNs into q.
        """
        w = np.asarray(tree.w_leaf)
        if self.positive_domain or self.required_dim is not None:
            self.validate_domain(np.asarray(tree.x_leaf)[w > 0])
        if self.name == "sqeuclidean":
            # no precomputed stats: block_div reads the given tree's own
            # S1/S2, so there is no cross-tree state to guard
            return BoundDivergence(div=self, stats=None)
        return BoundDivergence(div=self, stats=_compute_stats(self, tree),
                               _tree_ref=weakref.ref(tree))


def _compute_stats(div: Divergence, tree: PartitionTree) -> DivStats:
    w = tree.w_leaf
    # ghosts sit at the origin, which may be out of domain (KL/IS): substitute
    # the in-domain pad value; the w = 0 factor keeps their contribution zero
    x = jnp.where((w > 0)[:, None], tree.x_leaf, div.pad_value)
    g = div.grad_phi(x)
    return DivStats(
        sphi=_node_sums(div.phi(x) * w, tree.L),
        sg=_node_sums(g * w[:, None], tree.L),
        sgx=_node_sums((g * x).sum(-1) * w, tree.L),
    )


@dataclasses.dataclass(frozen=True)
class BoundDivergence:
    """A divergence bound to one tree: O(1)-per-block divergence evaluation.

    ``stats`` is ``None`` exactly for ``sqeuclidean``, whose block divergence
    reuses the tree's own ``S1``/``S2`` via the original eq.-9 formula —
    keeping the default path bit-identical to the Gaussian-only code.
    """

    div: Divergence
    stats: Optional[DivStats]
    # identity of the tree the stats were computed from (None for
    # sqeuclidean); block_div refuses a *different* tree even when it has
    # the same shape — mixing one tree's W/S1 with another's Bregman stats
    # would return finite but wrong divergences
    _tree_ref: Optional[weakref.ref] = None

    @property
    def name(self) -> str:
        return self.div.name

    def block_div(self, tree: PartitionTree, a: jax.Array, b: jax.Array) -> jax.Array:
        """D_AB = sum_{i in A, j in B} w_i w_j d_phi(x_i, x_j), O(1) per block."""
        wa, wb = tree.W[a], tree.W[b]
        if self.stats is None:  # sqeuclidean: the paper's eq. 9, verbatim
            d2 = wa * tree.S2[b] + wb * tree.S2[a] - 2.0 * (tree.S1[a] * tree.S1[b]).sum(-1)
            return jnp.maximum(d2, 0.0)
        if self._tree_ref is not None and self._tree_ref() is not tree:
            raise ValueError(
                f"divergence {self.name!r} was bound to a different tree; "
                f"re-bind with bind_divergence({self.name!r}, tree)")
        s = self.stats
        d = (wb * s.sphi[a] - wa * s.sphi[b]
             - (tree.S1[a] * s.sg[b]).sum(-1) + wa * s.sgx[b])
        return jnp.maximum(d, 0.0)

    # convenience pass-throughs so call sites hold one object
    def log_partition(self, dim, sigma) -> jax.Array:
        return self.div.log_partition(dim, sigma)

    def pairwise(self, xa: jax.Array, xb: jax.Array) -> jax.Array:
        return self.div.pairwise(xa, xb)


# =========================================================== the registry
_REGISTRY: dict[str, Divergence] = {}


def register_divergence(div: Divergence) -> Divergence:
    """Add ``div`` to the global registry (name must be unused)."""
    if div.name in _REGISTRY:
        raise ValueError(f"divergence {div.name!r} is already registered")
    _REGISTRY[div.name] = div
    return div


def get_divergence(name: str) -> Divergence:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown divergence {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def resolve_divergence(divergence) -> Divergence:
    """Canonicalize ``None`` | name | Divergence | BoundDivergence."""
    if divergence is None:
        return _REGISTRY["sqeuclidean"]
    if isinstance(divergence, BoundDivergence):
        return divergence.div
    if isinstance(divergence, Divergence):
        return divergence
    if isinstance(divergence, str):
        return get_divergence(divergence)
    raise TypeError(
        f"divergence must be None, a name, a Divergence or a BoundDivergence; "
        f"got {type(divergence).__name__}")


# bind memo: (divergence name, id(tree)) -> BoundDivergence.  Trees are
# immutable, so a bound divergence never goes stale; entries are evicted by
# a weakref finalizer when the tree is collected (before its id can be
# reused).  This makes the public qopt/sigma entry points — which accept an
# unbound divergence per call — pay the O(N d) stats pass and the host-side
# domain scan once per (divergence, tree), not once per call.
_BIND_CACHE: dict[tuple[str, int], BoundDivergence] = {}


def bind_divergence(divergence, tree: PartitionTree) -> BoundDivergence:
    """Resolve and bind in one step; already-bound divergences pass through."""
    if isinstance(divergence, BoundDivergence):
        return divergence
    div = resolve_divergence(divergence)
    key = (div.name, id(tree))
    hit = _BIND_CACHE.get(key)
    if hit is not None:
        return hit
    bound = div.bind(tree)
    _BIND_CACHE[key] = bound
    weakref.finalize(tree, _BIND_CACHE.pop, key, None)
    return bound


def adopt_bound(tree: PartitionTree, bound: BoundDivergence) -> BoundDivergence:
    """Seed the bind memo with an externally built :class:`BoundDivergence`.

    The streaming layer (``core/streaming.py``) patches the per-node Bregman
    stats incrementally instead of recomputing them via :meth:`Divergence.bind`
    — registering its patched bound here lets every later name-form
    ``bind_divergence(name, new_tree)`` call (qopt, sigma, refinement) reuse
    the O(k d log N)-patched stats rather than paying a fresh O(N d) pass.
    ``bound._tree_ref`` must already point at ``tree``.
    """
    if bound._tree_ref is not None and bound._tree_ref() is not tree:
        raise ValueError("adopt_bound: bound divergence references another tree")
    key = (bound.name, id(tree))
    _BIND_CACHE[key] = bound
    weakref.finalize(tree, _BIND_CACHE.pop, key, None)
    return bound


# ===================================================== concrete divergences
def _gaussian_log_partition(dim, sigma):
    return 0.5 * dim * jnp.log(2.0 * jnp.pi * sigma * sigma)


def _sqe_pairwise(xa, xb):
    an = (xa * xa).sum(-1)
    bn = (xb * xb).sum(-1)
    return (an[:, None] + bn[None, :]
            - 2.0 * jnp.dot(xa, xb.T, preferred_element_type=jnp.float32))


SQEUCLIDEAN = register_divergence(Divergence(
    name="sqeuclidean",
    _phi=lambda x: (x * x).sum(-1),
    _grad_phi=lambda x: 2.0 * x,
    _pairwise=_sqe_pairwise,
    _log_partition=_gaussian_log_partition,
))


def _kl_pairwise(xa, xb):
    # d(a, b) = sum_k a log(a/b) - a + b   (generalized KL)
    row = (xa * jnp.log(xa)).sum(-1) - xa.sum(-1)
    return (row[:, None] + xb.sum(-1)[None, :]
            - jnp.dot(xa, jnp.log(xb).T, preferred_element_type=jnp.float32))


KL = register_divergence(Divergence(
    name="kl",
    _phi=lambda x: (x * jnp.log(x)).sum(-1),
    _grad_phi=lambda x: jnp.log(x) + 1.0,
    _pairwise=_kl_pairwise,
    # surrogate Gaussian-form base measure: see Divergence.log_partition
    _log_partition=_gaussian_log_partition,
    pad_value=1.0,
    positive_domain=True,
))


def _is_pairwise(xa, xb):
    # d(a, b) = sum_k a/b - log(a/b) - 1
    d = xa.shape[-1]
    return (jnp.dot(xa, (1.0 / xb).T, preferred_element_type=jnp.float32)
            - jnp.log(xa).sum(-1)[:, None] + jnp.log(xb).sum(-1)[None, :]
            - float(d))


ITAKURA_SAITO = register_divergence(Divergence(
    name="itakura_saito",
    _phi=lambda x: -jnp.log(x).sum(-1),
    _grad_phi=lambda x: -1.0 / x,
    _pairwise=_is_pairwise,
    # surrogate Gaussian-form base measure: see Divergence.log_partition
    _log_partition=_gaussian_log_partition,
    pad_value=1.0,
    positive_domain=True,
))


def mahalanobis(scale) -> Divergence:
    """Diagonal Mahalanobis divergence ``d(a, b) = sum_k m_k (a_k - b_k)^2``.

    ``scale`` is the per-dimension metric ``m`` (strictly positive).  Each
    distinct scale yields its own named ``Divergence`` (the name embeds a
    fingerprint of ``m``), so two engines with different metrics never share
    a kernel executable.  ``phi(x) = sum_k m_k x_k^2``; the log-partition is
    the anisotropic-Gaussian normalizer ``d/2 log(2 pi s^2) - 1/2 sum log m``.
    """
    m_tuple = tuple(float(s) for s in np.asarray(scale, np.float64).reshape(-1))
    if not m_tuple or min(m_tuple) <= 0.0:
        raise ValueError(
            f"mahalanobis scale must be non-empty and strictly positive, "
            f"got {m_tuple}")
    # only the scalar identity gets the bare registry name: a length-k ones
    # vector pins required_dim=k, and names must imply behavior (the bind
    # cache and the jit static-arg dedup both key on the name)
    if len(m_tuple) == 1 and m_tuple[0] == 1.0:
        name = "mahalanobis"
    else:
        digest = hashlib.sha1(np.asarray(m_tuple).tobytes()).hexdigest()[:8]
        name = f"mahalanobis[{digest}]"
    log_m = np.log(np.asarray(m_tuple))  # pure numpy: no JAX init at import

    # the jnp scale constant is built lazily inside each closure (not at
    # factory time) so merely importing/registering divergences never
    # initializes the JAX backend
    def _m():
        return jnp.asarray(m_tuple, jnp.float32)

    def log_part(dim, sigma):
        # a length-1 scale broadcasts over all dim coordinates, so its
        # normalizer term counts dim times; an explicit vector counts once
        # per entry (its length is pinned to d via required_dim)
        metric = dim * float(log_m[0]) if len(m_tuple) == 1 else float(log_m.sum())
        return _gaussian_log_partition(dim, sigma) - 0.5 * metric

    return Divergence(
        name=name,
        _phi=lambda x: (_m() * x * x).sum(-1),
        _grad_phi=lambda x: 2.0 * _m() * x,
        _pairwise=lambda xa, xb: _sqe_pairwise(xa * jnp.sqrt(_m()),
                                               xb * jnp.sqrt(_m())),
        _log_partition=log_part,
        _transform=lambda x: x * jnp.sqrt(_m()),
        required_dim=len(m_tuple) if len(m_tuple) > 1 else None,
    )


MAHALANOBIS = register_divergence(mahalanobis(np.ones(1)))

# public view of the registry (read-only by convention)
DIVERGENCES = _REGISTRY
