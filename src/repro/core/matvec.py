"""O(|B|) matrix-vector multiplication with the block transition matrix.

Vectorized form of the paper's Algorithm 1 (with the DistributeDown typo
fixed — see DESIGN.md):

    (QY)_i = sum_{(A,B) in B(x_i)} q_AB * T_B,   T_B = sum_{j in B} y_j

  CollectUp      -> level-major reshape sums produce T for all nodes, O(N C)
  per-block      -> c_block = q_AB * T[b];  segment-sum into c_node, O(|B| C)
  DistributeDown -> top-down prefix accumulation over levels, O(N C)

Leaves read their accumulated path sum.  Ghost leaves hold y = 0 so they
contribute nothing and receive garbage that is never read back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tree import PartitionTree

__all__ = ["collect_up", "mpt_matvec", "mpt_matvec_leaforder"]


@functools.partial(jax.jit, static_argnames=("L",))
def collect_up(y_leaf: jax.Array, L: int) -> jax.Array:
    """Per-node sums T (n_nodes, C) from leaf values (Np, C)."""
    levels = [y_leaf]
    cur = y_leaf
    for _ in range(L):
        cur = cur.reshape(-1, 2, cur.shape[-1]).sum(axis=1)
        levels.append(cur)
    return jnp.concatenate(levels[::-1], axis=0)


@functools.partial(jax.jit, static_argnames=("L",))
def _distribute_down(c_node: jax.Array, L: int) -> jax.Array:
    """Top-down prefix accumulation; returns per-leaf path sums (Np, C)."""
    acc = c_node[0:1]  # root, (1, C)
    for lvl in range(L):
        lo, hi = (1 << (lvl + 1)) - 1, (1 << (lvl + 2)) - 1
        children = c_node[lo:hi]
        acc = jnp.repeat(acc, 2, axis=0) + children
    return acc


@functools.partial(jax.jit, static_argnames=("L",))
def mpt_matvec_leaforder(
    y_leaf: jax.Array,       # (Np, C) values in leaf order (ghosts 0)
    a: jax.Array,            # (cap,)
    b: jax.Array,            # (cap,)
    q: jax.Array,            # (cap,)  block parameters (0 where inactive)
    L: int,
) -> jax.Array:
    """(QY) in leaf order."""
    n_nodes = (1 << (L + 1)) - 1
    t = collect_up(y_leaf, L)                       # (n_nodes, C)
    c_block = q[:, None] * t[b]                     # (cap, C)
    c_node = jax.ops.segment_sum(c_block, a, num_segments=n_nodes)
    return _distribute_down(c_node, L)


def mpt_matvec(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    y: jax.Array,            # (N, C) in original row order
) -> jax.Array:
    """(QY) in original row order; O(|B| C + N C)."""
    y = jnp.asarray(y)
    squeeze = y.ndim == 1
    if squeeze:
        y = y[:, None]
    q = jnp.where(active & jnp.isfinite(log_q), jnp.exp(log_q), 0.0)
    y_leaf = jnp.zeros((tree.n_leaves, y.shape[1]), dtype=y.dtype)
    y_leaf = y_leaf.at[tree.slot_of].set(y)
    out_leaf = mpt_matvec_leaforder(y_leaf, a, b, q, tree.L)
    out = out_leaf[tree.slot_of]
    return out[:, 0] if squeeze else out
