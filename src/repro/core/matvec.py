"""O(|B|) matrix-vector multiplication with the block transition matrix.

Vectorized form of the paper's Algorithm 1 (with the DistributeDown typo
fixed — see DESIGN.md):

    (QY)_i = sum_{(A,B) in B(x_i)} q_AB * T_B,   T_B = sum_{j in B} y_j

  CollectUp      -> level-major reshape sums produce T for all nodes, O(N C)
  per-block      -> c_block = q_AB * T[b];  segment-sum into c_node, O(|B| C)
  DistributeDown -> top-down prefix accumulation over levels, O(N C)

Leaves read their accumulated path sum.  Ghost leaves hold y = 0 so they
contribute nothing and receive garbage that is never read back.

Batched multi-RHS
-----------------
Every step of Algorithm 1 is linear and acts only on the trailing channel
axis, so a stacked right-hand side ``Y`` of shape ``(batch, N, C)`` can be
served two equivalent ways:

  * **level-major batched** — ``collect_up`` / ``_distribute_down`` /
    ``mpt_matvec_leaforder`` accept arbitrary leading batch dims natively
    (the reshapes and the segment-sum simply carry the extra axes);
  * **channel-folded** — fold the batch into the channel axis,
    ``(batch, N, C) -> (N, batch * C)``, run the single-RHS path once, and
    unfold.  One CollectUp, one segment-sum, and one DistributeDown serve
    the whole batch, so per-call dispatch and gather/scatter overhead is
    paid once instead of ``batch`` times.

``mpt_matvec`` auto-detects a 3-D ``y`` and takes the channel-folded fast
path; ``mpt_matvec_batched`` is the explicit spelling.  Parity of both paths
against stacked single-RHS calls (and against the dense ``Q @ Y``) is pinned
in ``tests/test_batched.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tree import PartitionTree

__all__ = [
    "collect_up",
    "fold_batch",
    "mpt_matvec",
    "mpt_matvec_batched",
    "mpt_matvec_leaforder",
    "prepare_q",
    "unfold_batch",
]


def prepare_q(active: jax.Array, log_q: jax.Array) -> jax.Array:
    """Block weights ``q = exp(log_q)`` with inactive/-inf entries zeroed.

    Hoist this out of per-iteration / per-request paths: a fitted tree's q
    never changes between refinements, so serving code computes it once and
    reuses the buffer across scheduler iterations instead of re-exponentiating
    inside every scan step.
    """
    return jnp.where(active & jnp.isfinite(log_q), jnp.exp(log_q), 0.0)


def fold_batch(ys: jax.Array) -> jax.Array:
    """(batch, N, C) -> (N, batch * C); the canonical channel folding.

    Single source of truth for the folded layout: folded column ``b*C + ch``
    holds batch ``b``, channel ``ch`` (per-batch channel blocks, batch-major
    across blocks); ``unfold_batch`` is its inverse.
    """
    batch, n, c = ys.shape
    return jnp.moveaxis(ys, 0, 1).reshape(n, batch * c)


def unfold_batch(y: jax.Array, batch: int, c: int) -> jax.Array:
    """(N, batch * C) -> (batch, N, C); inverse of ``fold_batch``."""
    return jnp.moveaxis(y.reshape(y.shape[0], batch, c), 1, 0)


@functools.partial(jax.jit, static_argnames=("L",))
def collect_up(y_leaf: jax.Array, L: int) -> jax.Array:
    """Per-node sums T (..., n_nodes, C) from leaf values (..., Np, C).

    Leading batch dims are carried through untouched — the level-major
    reshape sums only ever touch the last two axes.
    """
    levels = [y_leaf]
    cur = y_leaf
    for _ in range(L):
        cur = cur.reshape(*cur.shape[:-2], -1, 2, cur.shape[-1]).sum(axis=-2)
        # Pin the summation tree: each level must be computed FROM the
        # materialized level below it.  Without the barrier XLA is free to
        # fuse the tiny top levels into one reduction straight from a lower
        # level with a different association order, and which rewrite fires
        # depends on the surrounding program — so the same tree summed
        # inside two different jits (e.g. the single-device scan vs the
        # sharded engine's shard_map body) can disagree by ulps.  The
        # serving tier promises cross-engine *bit* parity, so the order is
        # part of the contract.
        cur = jax.lax.optimization_barrier(cur)
        levels.append(cur)
    return jnp.concatenate(levels[::-1], axis=-2)


@functools.partial(jax.jit, static_argnames=("L",))
def _distribute_down(c_node: jax.Array, L: int) -> jax.Array:
    """Top-down prefix accumulation; returns per-leaf path sums (..., Np, C)."""
    acc = c_node[..., 0:1, :]  # root, (..., 1, C)
    for lvl in range(L):
        lo, hi = (1 << (lvl + 1)) - 1, (1 << (lvl + 2)) - 1
        children = c_node[..., lo:hi, :]
        acc = jnp.repeat(acc, 2, axis=-2) + children
    return acc


@functools.partial(jax.jit, static_argnames=("L",))
def mpt_matvec_leaforder(
    y_leaf: jax.Array,       # (..., Np, C) values in leaf order (ghosts 0)
    a: jax.Array,            # (cap,)
    b: jax.Array,            # (cap,)
    q: jax.Array,            # (cap,)  block parameters (0 where inactive)
    L: int,
) -> jax.Array:
    """(QY) in leaf order; any leading batch dims ride along level-major."""
    n_nodes = (1 << (L + 1)) - 1
    t = collect_up(y_leaf, L)                       # (..., n_nodes, C)
    c_block = q[:, None] * jnp.take(t, b, axis=-2)  # (..., cap, C)
    c_block = jnp.moveaxis(c_block, -2, 0)          # (cap, ..., C)
    c_node = jax.ops.segment_sum(c_block, a, num_segments=n_nodes)
    c_node = jnp.moveaxis(c_node, 0, -2)            # (..., n_nodes, C)
    return _distribute_down(c_node, L)


def mpt_matvec(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    y: jax.Array,            # (N,), (N, C) or (batch, N, C) in row order
) -> jax.Array:
    """(QY) in original row order; O(|B| C + N C).

    A 3-D ``y`` of shape ``(batch, N, C)`` is served by one device dispatch
    via channel folding: ``(batch, N, C) -> (N, batch * C)``.
    """
    y = jnp.asarray(y)
    if y.ndim == 3:
        batch, _, c = y.shape
        out = mpt_matvec(tree, a, b, active, log_q, fold_batch(y))
        return unfold_batch(out, batch, c)
    squeeze = y.ndim == 1
    if squeeze:
        y = y[:, None]
    q = prepare_q(active, log_q)
    y_leaf = jnp.zeros((tree.n_leaves, y.shape[1]), dtype=y.dtype)
    y_leaf = y_leaf.at[tree.slot_of].set(y)
    out_leaf = mpt_matvec_leaforder(y_leaf, a, b, q, tree.L)
    out = out_leaf[tree.slot_of]
    return out[:, 0] if squeeze else out


def mpt_matvec_batched(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    ys: jax.Array,           # (batch, N, C) in original row order
) -> jax.Array:
    """Explicit batched multi-RHS (Q @ Y_b for every b) in one dispatch."""
    ys = jnp.asarray(ys)
    if ys.ndim != 3:
        raise ValueError(f"mpt_matvec_batched wants (batch, N, C), got {ys.shape}")
    return mpt_matvec(tree, a, b, active, log_q, ys)
