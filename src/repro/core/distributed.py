"""Distributed VDT: the paper's random-walk inference at pod scale.

The MPT matvec (Algorithm 1) decomposes into

  CollectUp      — per-level reshape sums over the leaf axis       (local +
                   log-depth cross-shard reductions, tiny upper levels)
  block combine  — c_block = q * T[b];  segment-sum by a-node      (gather +
                   scatter-add; blocks sharded, node table replicated above
                   the shard level)
  DistributeDown — prefix accumulation over levels                 (local)

Sharding strategy for the production mesh: leaves and blocks are sharded
over the *entire* device grid (both ``data`` and ``model`` axes flattened —
the paper's workload has no tensor dimension to model-shard, so all 256/512
devices act as data shards).  Upper tree levels are tiny (2^l nodes) and are
left replicated; GSPMD turns the cross-shard leaf reductions into
reduce-scatters.

``lp_step_leaforder`` is what the dry-run lowers for the ``paper_vdt`` cell;
``label_propagate_distributed`` scans it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.matvec import collect_up

__all__ = ["lp_step_leaforder", "label_propagate_distributed",
           "vdt_input_specs"]


@functools.partial(jax.jit,
                   static_argnames=("L", "sorted_blocks", "carrier_dtype"))
def lp_step_leaforder(
    y_leaf: jax.Array,      # (Np, C) labels in leaf order (ghosts 0)
    y0_leaf: jax.Array,     # (Np, C) anchor labels
    a: jax.Array,           # (nb,) block data-node ids
    b: jax.Array,           # (nb,) block kernel-node ids
    q: jax.Array,           # (nb,) block transition parameters
    alpha: float,
    L: int,
    sorted_blocks: bool = False,   # §Perf: blocks pre-sorted by a-node
    carrier_dtype=None,            # §Perf: bf16 carriers halve HBM traffic
) -> jax.Array:
    """One Label-Propagation step  y <- alpha Q y + (1 - alpha) y0."""
    n_nodes = (1 << (L + 1)) - 1
    dt = carrier_dtype or y_leaf.dtype
    t = collect_up(y_leaf.astype(dt), L)               # (n_nodes, C)
    c_block = q.astype(dt)[:, None] * t[b]             # (nb, C) gather
    c_node = jax.ops.segment_sum(
        c_block, a, num_segments=n_nodes,
        indices_are_sorted=sorted_blocks)
    # distribute down: prefix accumulate root -> leaves
    acc = c_node[0:1]
    for lvl in range(L):
        lo, hi = (1 << (lvl + 1)) - 1, (1 << (lvl + 2)) - 1
        acc = jnp.repeat(acc, 2, axis=0) + c_node[lo:hi]
    return (alpha * acc.astype(y_leaf.dtype)
            + (1.0 - alpha) * y0_leaf)


def label_propagate_distributed(y0_leaf, a, b, q, alpha: float, L: int,
                                n_iters: int):
    def step(y, _):
        return lp_step_leaforder(y, y0_leaf, a, b, q, alpha, L), None

    y, _ = jax.lax.scan(step, y0_leaf, None, length=n_iters)
    return y


def vdt_input_specs(n_points: int = 1 << 20, n_classes: int = 16,
                    blocks_per_point: int = 4):
    """ShapeDtypeStruct stand-ins for the paper_vdt dry-run cell.

    N = 2^20 leaves, C = 16 label classes, |B| = 4N blocks — the scale of
    the paper's Table 2 'alpha' experiment (0.5M points, 1M-4M params).
    """
    import math

    L = int(math.log2(n_points))
    nb = blocks_per_point * n_points
    f32, i32 = jnp.float32, jnp.int32
    return {
        "y_leaf": jax.ShapeDtypeStruct((n_points, n_classes), f32),
        "y0_leaf": jax.ShapeDtypeStruct((n_points, n_classes), f32),
        "a": jax.ShapeDtypeStruct((nb,), i32),
        "b": jax.ShapeDtypeStruct((nb,), i32),
        "q": jax.ShapeDtypeStruct((nb,), f32),
    }, {"L": L, "tokens_per_step": n_points}
