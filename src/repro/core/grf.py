"""GRF backend: unbiased Monte-Carlo transition-matrix action by random walks.

The third serving backend (graph random features, arXiv:2305.00156 /
2410.10368).  Where ``"vdt"`` serves the fitted variational approximation Q
and ``"exact"`` streams the dense eq.-3 matrix P, ``"grf"`` never touches
P's rows at all: every node launches ``n_walkers`` terminating random
walks over a sparse CSR neighbor table, and the load-weighted walker mean

    est[i, :] = (1/m) * sum_w load_t[i, w] * Y[pos_t[i, w], :]

is an **unbiased** estimate of ``(P^t @ Y)[i, :]`` (see
``kernels/grf/walkers.py`` for the importance-weighting argument).  Cost
per step is O(N * m) — independent of edge count and of N^2 — which opens
sparse-graph workloads the dual tree cannot touch and gives a per-request
accuracy dial: the relative error of an m-walker mean scales as
``O(1 / sqrt(m))`` (CLT), so ``m ~= 1 / rtol^2`` walkers buy a target
relative tolerance (:func:`walkers_for_rtol`).

Label propagation composes from walk prefixes.  Unrolling eq. 15,

    Y_T = sum_{t<T} (1-a) a^t P^t Y_0  +  a^T P^T Y_0,

so ONE walk set of horizon T estimates every term at once: the step-t
walker population estimates ``P^t Y_0``, weighted by the series
coefficient ``(1-a) a^t`` (or ``a^T`` for the final term).
:func:`grf_label_propagate` streams this: one ``lax.scan`` advances the
walkers and accumulates coefficient-weighted feature products, O(N * m)
memory, never storing walk histories.  Per-column coefficients make
heterogeneous alphas exact in one dispatch (LP is column-independent),
matching the serving tier's coalescing contract.

Graphs come in two ways: natively sparse via :meth:`CSRGraph.from_csr`
(neighbor lists — the workload this backend exists for), or bridged from
the existing point-cloud path via :meth:`CSRGraph.from_points`, which
materializes the dense eq.-3 kernel row-softmax once (O(N^2) — fine at
validation sizes, and what makes GRF differentially testable against the
exact backend).  Positive-domain Bregman divergences (KL, Itakura-Saito)
are rejected: their kernel rows need the dual-tree subtree-stats
machinery at every visited node, which a walker does not carry.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.grf.grf import grf_feature_kernel
from repro.kernels.grf.ref import grf_feature_matvec_ref
from repro.kernels.grf.walkers import sample_walks as _sample_walks
from repro.kernels.grf.walkers import walk_step

__all__ = ["CSRGraph", "DEFAULT_N_WALKERS", "MAX_RTOL_WALKERS",
           "walkers_for_rtol", "sample_walks", "grf_transition_action",
           "grf_label_propagate"]

# serving default walker budget: rel. error ~ 1/sqrt(64) = 12.5% per step
# estimate — the latency-lean end of the dial; requests wanting tighter
# pass n_walkers or rtol explicitly
DEFAULT_N_WALKERS = 64

# cap on rtol-derived budgets: 1/rtol^2 explodes as rtol -> 0, and a
# request wanting that much accuracy should ride "exact"/"vdt" instead
# (route_backend("auto") refuses grf below AUTO_GRF_MIN_RTOL for the same
# reason) — the cap just keeps an explicit backend="grf" + tiny-rtol
# request from allocating an absurd walker population
MAX_RTOL_WALKERS = 4096


def walkers_for_rtol(rtol: float) -> int:
    """Walker budget for a target relative tolerance: ``ceil(1 / rtol^2)``.

    CLT sizing: the m-walker mean's relative standard error is
    ``sigma_rel / sqrt(m)`` with ``sigma_rel = O(1)`` for row-stochastic
    loads, so ``m = 1 / rtol^2`` puts one standard error at ``rtol``.
    Clamped to ``[1, MAX_RTOL_WALKERS]``.
    """
    rtol = float(rtol)
    if not (rtol > 0.0):
        raise ValueError(f"rtol must be > 0, got {rtol}")
    return max(1, min(MAX_RTOL_WALKERS, math.ceil(1.0 / (rtol * rtol))))


def _check_divergence(divergence) -> None:
    from repro.core.divergence import resolve_divergence

    div = resolve_divergence(divergence)
    if not div.euclidean_after_transform:
        raise ValueError(
            f"backend='grf' does not support divergence {div.name!r}: "
            f"positive-domain Bregman kernels (kl, itakura_saito) need the "
            f"dual-tree subtree-stats factorization at every visited node, "
            f"which a random walker does not carry; use backend='vdt' or "
            f"'exact'")


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """A row-stochastic sparse transition matrix in padded device layout.

    ``nbr[i, k]`` / ``prob[i, k]`` are node i's k-th neighbor and its
    transition probability for ``k < deg[i]`` (padding slots hold
    neighbor 0 with probability 0 — inert under the walkers' load
    weighting).  Rows are normalized to sum to 1 at construction, so the
    dense scatter :meth:`dense_p` is row-stochastic by construction.
    """

    nbr: jax.Array    # (N, max_deg) int32 padded neighbor table
    prob: jax.Array   # (N, max_deg) f32 transition probs, padding 0
    deg: jax.Array    # (N,) int32 true neighbor counts
    n: int
    nnz: int

    @property
    def max_deg(self) -> int:
        return int(self.nbr.shape[1])

    @property
    def density(self) -> float:
        """Edge fraction ``nnz / N^2`` — the :func:`route_backend` signal."""
        return self.nnz / float(self.n * self.n)

    @classmethod
    def from_csr(cls, indptr, indices, weights=None) -> "CSRGraph":
        """Build from CSR neighbor lists; weights default to uniform.

        Validates the structure a random walk needs: monotone ``indptr``,
        in-range ``indices``, every row at least one outgoing edge (a
        dangling node has no transition distribution), and non-negative
        finite ``weights`` with positive row sums.
        """
        indptr = np.asarray(indptr, np.int64)
        indices = np.asarray(indices, np.int64)
        if indptr.ndim != 1 or indptr.size < 2:
            raise ValueError(f"indptr must be (N+1,), got {indptr.shape}")
        n = indptr.size - 1
        deg = np.diff(indptr)
        if indptr[0] != 0 or indptr[-1] != indices.size or (deg < 0).any():
            raise ValueError("indptr must be monotone from 0 to len(indices)")
        if (deg < 1).any():
            rows = np.nonzero(deg < 1)[0][:5].tolist()
            raise ValueError(
                f"every node needs >= 1 outgoing edge for a random walk; "
                f"rows {rows} have none")
        if indices.size and (indices.min() < 0 or indices.max() >= n):
            raise ValueError(f"indices must lie in [0, {n}), got range "
                             f"[{indices.min()}, {indices.max()}]")
        if weights is None:
            weights = np.ones(indices.size, np.float64)
        else:
            weights = np.asarray(weights, np.float64)
            if weights.shape != indices.shape:
                raise ValueError(
                    f"weights shape {weights.shape} != indices "
                    f"shape {indices.shape}")
            if not np.isfinite(weights).all() or (weights < 0).any():
                raise ValueError("weights must be finite and >= 0")
        max_deg = int(deg.max())
        mask = np.arange(max_deg)[None, :] < deg[:, None]   # (N, max_deg)
        nbr = np.zeros((n, max_deg), np.int32)
        nbr[mask] = indices                      # CSR order is row-major
        w = np.zeros((n, max_deg), np.float64)
        w[mask] = weights
        row_sum = w.sum(axis=1)
        if (row_sum <= 0).any():
            rows = np.nonzero(row_sum <= 0)[0][:5].tolist()
            raise ValueError(
                f"rows {rows} have zero total weight — no transition "
                f"distribution to walk")
        prob = (w / row_sum[:, None]).astype(np.float32)
        return cls(nbr=jnp.asarray(nbr), prob=jnp.asarray(prob),
                   deg=jnp.asarray(deg.astype(np.int32)), n=n,
                   nnz=int(deg.sum()))

    @classmethod
    def from_dense(cls, p, atol: float = 0.0) -> "CSRGraph":
        """Sparsify a dense transition matrix (entries ``> atol`` kept)."""
        p = np.asarray(p, np.float64)
        if p.ndim != 2 or p.shape[0] != p.shape[1]:
            raise ValueError(f"p must be square (N, N), got {p.shape}")
        keep = p > atol
        rows, cols = np.nonzero(keep)
        indptr = np.zeros(p.shape[0] + 1, np.int64)
        np.cumsum(np.bincount(rows, minlength=p.shape[0]), out=indptr[1:])
        return cls.from_csr(indptr, cols, p[rows, cols])

    @classmethod
    def from_points(cls, x, sigma, divergence=None) -> "CSRGraph":
        """Bridge from the point-cloud path: the dense eq.-3 kernel graph.

        Materializes the row-softmax transition matrix once (O(N^2) —
        validation/analysis sizes), so GRF estimates converge to exactly
        the matrix the ``"exact"`` backend walks.  Raises ``ValueError``
        for positive-domain divergences (see module docstring).
        """
        from repro.kernels.fused_lp.ref import dense_transition_ref

        _check_divergence(divergence)
        p = np.asarray(dense_transition_ref(x, float(sigma),
                                            divergence=divergence))
        return cls.from_dense(p)

    def dense_p(self) -> np.ndarray:
        """Scatter back to the dense ``(N, N)`` matrix — the test oracle."""
        deg = np.asarray(self.deg)
        mask = np.arange(self.max_deg)[None, :] < deg[:, None]
        p = np.zeros((self.n, self.n), np.float32)
        rows = np.broadcast_to(np.arange(self.n)[:, None], mask.shape)[mask]
        np.add.at(p, (rows, np.asarray(self.nbr)[mask]),
                  np.asarray(self.prob)[mask])
        return p


def sample_walks(graph: CSRGraph, *, n_steps: int, n_walkers: int,
                 seed: int = 0, p_halt: float = 0.0):
    """Walk histories for ``graph``: ``(pos, load)``, ``(N, m, T+1)`` each."""
    key = jax.random.PRNGKey(int(seed))
    return _sample_walks(graph.nbr, graph.prob, graph.deg, key,
                         n_steps=int(n_steps), n_walkers=int(n_walkers),
                         p_halt=float(p_halt))


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _feature(pos, load, y, impl):
    if impl == "ref":
        return grf_feature_matvec_ref(pos, load, y)
    if impl is not None:
        raise ValueError(f"impl must be None (Pallas) or 'ref', got {impl!r}")
    return grf_feature_kernel(pos, load, y, interpret=_interpret())


def grf_transition_action(graph: CSRGraph, y, *, t: int,
                          n_walkers: int = DEFAULT_N_WALKERS, seed: int = 0,
                          p_halt: float = 0.0, return_samples: bool = False,
                          impl: Optional[str] = None):
    """Unbiased MC estimate of ``P^t @ Y`` without materializing P.

    ``y`` is ``(N,)`` or ``(N, C)``; the estimate matches its shape.  With
    ``return_samples=True`` also returns the per-walker contributions
    ``(N, m, C)`` whose walker-axis mean IS the estimate — the statistical
    harness derives its CLT confidence bounds from their spread.
    ``impl`` selects the feature reduction (``None`` = Pallas kernel,
    ``"ref"`` = jnp oracle); the estimate is the same either way.
    """
    y = jnp.asarray(y)
    squeeze = y.ndim == 1
    y2 = y[:, None] if squeeze else y
    pos, load = sample_walks(graph, n_steps=int(t), n_walkers=n_walkers,
                             seed=seed, p_halt=p_halt)
    pos_t, load_t = pos[:, :, int(t)], load[:, :, int(t)]
    est = _feature(pos_t, load_t, y2.astype(jnp.float32), impl)
    est = est[:, 0] if squeeze else est
    if return_samples:
        samples = (jnp.take(y2.astype(jnp.float32), pos_t, axis=0)
                   * load_t[..., None])
        return est, (samples[:, :, 0] if squeeze else samples)
    return est


def grf_label_propagate(graph: CSRGraph, y0, alpha=0.01, n_iters: int = 500,
                        *, n_walkers: int = DEFAULT_N_WALKERS, seed: int = 0,
                        p_halt: float = 0.0, impl: Optional[str] = None):
    """Eq.-15 label propagation estimated from one streamed walk set.

    ``y0`` is ``(N,)``, ``(N, C)`` or ``(batch, N, C)``; ``alpha`` a
    scalar, per-column ``(C,)`` (2-D), or per-request ``(batch,)`` (3-D) —
    the same shape/alpha contract as ``VariationalDualTree
    .label_propagate``, so the serving tier coalesces GRF groups exactly
    like the other backends (batch folds into the channel axis; walker
    paths are label-independent, so the whole folded stack shares ONE walk
    set).  Deterministic per ``(seed, shapes)``: repeated dispatches are
    bit-identical.
    """
    from repro.core import matvec as matvec_mod

    y0 = jnp.asarray(y0)
    if not jnp.issubdtype(y0.dtype, jnp.floating):
        y0 = y0.astype(jnp.float32)
    if int(n_iters) < 0:
        raise ValueError(f"n_iters must be >= 0, got {n_iters}")
    if y0.ndim == 3:
        batch, _, c = y0.shape
        alpha = jnp.asarray(alpha, jnp.float32)
        if alpha.ndim == 1:
            if alpha.shape[0] != batch:
                raise ValueError(
                    f"per-request alpha wants shape ({batch},), "
                    f"got {alpha.shape}")
            # folded column b*C + ch belongs to request b (see fold_batch)
            alpha = jnp.repeat(alpha, c)
        out = grf_label_propagate(
            graph, matvec_mod.fold_batch(y0), alpha=alpha, n_iters=n_iters,
            n_walkers=n_walkers, seed=seed, p_halt=p_halt, impl=impl)
        return matvec_mod.unfold_batch(out, batch, c)
    squeeze = y0.ndim == 1
    if squeeze:
        y0 = y0[:, None]
    alpha = jnp.asarray(alpha, jnp.float32)
    if alpha.ndim == 1 and alpha.shape[0] != y0.shape[1]:
        raise ValueError(
            f"per-column alpha wants shape ({y0.shape[1]},), "
            f"got {alpha.shape}")
    alpha_cols = jnp.broadcast_to(alpha, (y0.shape[1],))
    out = _lp_streamed(graph.nbr, graph.prob, graph.deg,
                       y0.astype(jnp.float32), alpha_cols,
                       jax.random.PRNGKey(int(seed)), int(n_iters),
                       int(n_walkers), float(p_halt), impl)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "n_walkers", "p_halt", "impl"))
def _lp_streamed(nbr, prob, deg, y0, alpha_cols, key, n_iters: int,
                 n_walkers: int, p_halt: float, impl):
    """One scan: advance walkers + accumulate series-weighted features.

    Carry is O(N * m + N * K): walker state plus the running estimate.
    Coefficients follow the eq.-15 unroll — ``(1 - a) a^t`` for ``t <
    n_iters`` and ``a^T`` for the final term — per folded column, so
    heterogeneous alphas are exact.  Step t's randomness is
    ``fold_in(key_w, t)`` with t in 1..T, matching ``sample_walks``
    bit-for-bit (the differential tests lean on this).
    """
    n, k = y0.shape
    t_steps = int(n_iters)
    t_idx = jnp.arange(t_steps + 1, dtype=jnp.float32)[:, None]  # (T+1, 1)
    a = alpha_cols[None, :]                                      # (1, K)
    coeff = a ** t_idx
    coeff = jnp.where(t_idx < t_steps, (1.0 - a) * coeff, coeff)  # (T+1, K)
    acc = coeff[0][None, :] * y0  # t=0 features are exactly y0 (load 1)
    if t_steps == 0:
        return acc
    w = n * n_walkers
    start = jnp.repeat(jnp.arange(n, dtype=jnp.int32), n_walkers)
    wkeys = jax.random.split(key, w)

    def body(carry, t):
        pos, load, alive, acc = carry
        pos, load, alive = walk_step(nbr, prob, deg, pos, load, alive,
                                     wkeys, t, p_halt)
        feat = _feature(pos.reshape(n, n_walkers),
                        load.reshape(n, n_walkers), y0, impl)
        acc = acc + coeff[t][None, :] * feat
        return (pos, load, alive, acc), None

    init = (start, jnp.ones((w,), jnp.float32), jnp.ones((w,), bool), acc)
    (_, _, _, acc), _ = jax.lax.scan(
        body, init, jnp.arange(1, t_steps + 1, dtype=jnp.int32))
    return acc
