"""Greedy block refinement (paper §4.4), batched for TPU.

Horizontal refinement of block (A, B) replaces it by {(A, B_l), (A, B_r)}.
The closed-form lower bound on its log-likelihood gain (eq. 19):

    Delta_h(A, B) = W_A W_B q_AB * log( sum_t W_{B_t} e^{G_{A B_t}}
                                        / (W_B e^{G_AB}) )

Gains are >= 0 by Jensen.  *Symmetric refinement*: picking (A, B) also
horizontally refines its mirror (B, A) (the paper's stand-in for vertical
refinement, which has no closed-form gain).

TPU adaptation: the paper pops one block at a time off a priority queue; we
compute all gains vectorized, take the top-k in one shot, apply the union of
picked blocks and their mirrors, then globally re-optimize q (O(|B|)).  k = 1
recovers the paper's schedule exactly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockPartition
from repro.core.qopt import QState, block_log_G, optimize_q
from repro.core.tree import PartitionTree

__all__ = ["refinement_gains", "refine_topk", "refine_to_budget"]


@jax.jit
def _gains_impl(W, log_g, log_gl, log_gr, wb, wbl, wbr, log_q, refinable):
    lse = jnp.logaddexp(
        jnp.where(wbl > 0, jnp.log(jnp.maximum(wbl, 1e-12)) + log_gl, -jnp.inf),
        jnp.where(wbr > 0, jnp.log(jnp.maximum(wbr, 1e-12)) + log_gr, -jnp.inf),
    )
    parent = jnp.log(jnp.maximum(wb, 1e-12)) + log_g
    gain_log = lse - parent
    q = jnp.where(jnp.isfinite(log_q), jnp.exp(log_q), 0.0)
    del W
    gains = jnp.where(
        refinable & jnp.isfinite(gain_log), q * jnp.maximum(gain_log, 0.0), -jnp.inf
    )
    return gains


def refinement_gains(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    sigma: jax.Array,
    divergence=None,
) -> jax.Array:
    """Delta_h * (W_A W_B)^{-1}-free gains for all blocks; −inf if unrefinable.

    Returns the *total* gain W_A W_B q_AB log(...) per block (eq. 19).
    """
    n_leaf_first = tree.n_internal  # first leaf id
    wa, wb = tree.W[a], tree.W[b]
    b_internal = b < n_leaf_first
    bl = jnp.where(b_internal, 2 * b + 1, b)
    br = jnp.where(b_internal, 2 * b + 2, b)
    from repro.core.divergence import bind_divergence
    div = bind_divergence(divergence, tree)  # bind stats once for all 3 calls
    log_g = block_log_G(tree, a, b, active, sigma, divergence=div)
    log_gl = block_log_G(tree, a, bl, active, sigma, divergence=div)
    log_gr = block_log_G(tree, a, br, active, sigma, divergence=div)
    refinable = active & b_internal & (wa > 0) & (wb > 0)
    raw = _gains_impl(tree.W, log_g, log_gl, log_gr,
                      wb, tree.W[bl], tree.W[br], log_q, refinable)
    return jnp.where(refinable, wa * wb * raw, -jnp.inf)


def refine_topk(
    bp: BlockPartition,
    tree: PartitionTree,
    gains: np.ndarray,
    k: int,
    stale: np.ndarray | None = None,
) -> int:
    """Apply symmetric refinement to the top-k blocks by gain (host-side).

    Returns the number of blocks actually refined.  Each refined block is
    deactivated and replaced by its two horizontal children; mirrors of the
    new blocks are wired up when both sides of a symmetric pair refine.

    ``stale`` (optional (>= bp.n,) bool array) marks blocks whose statistics
    were patched by streaming inserts/deletes since the last refinement:
    stale blocks with a finite gain are refined FIRST (gain-ordered among
    themselves), so the block budget is spent where the fitted structure is
    most out of date.  Refined slots have their stale flag cleared in place.
    """
    g = np.asarray(gains[: bp.n], dtype=np.float64)
    g[~bp.active[: bp.n]] = -np.inf
    if stale is not None:
        # stale arrays are sized to the partition they were created for;
        # blocks appended by earlier refinement rounds are implicitly fresh
        s = np.zeros(bp.n, bool)
        m = min(len(stale), bp.n)
        s[:m] = np.asarray(stale[:m], bool)
        # primary key: stale first; secondary: gain descending (lexsort
        # reads keys last-to-first)
        order = np.lexsort((-g, ~s))
    else:
        order = np.argsort(-g)
    picked: list[int] = []
    seen: set[int] = set()
    for idx in order[: 4 * k]:
        if len(picked) >= k or not np.isfinite(g[idx]):
            break
        i = int(idx)
        if i in seen:
            continue
        picked.append(i)
        seen.add(i)
        m = int(bp.mirror[i])
        if m >= 0 and bp.active[m] and m not in seen:
            # symmetric refinement: mirror is refined too (doesn't count
            # against k — it is the paper's vertical-refinement stand-in)
            picked.append(m)
            seen.add(m)
    if not picked:
        return 0

    w = np.asarray(tree.W)
    new_a, new_b = [], []
    for i in picked:
        ai, bi = int(bp.a[i]), int(bp.b[i])
        for bc in (2 * bi + 1, 2 * bi + 2):
            # children whose kernel side is all-ghost cover no real pair;
            # skipping them keeps the fitted block layout (and its log_q
            # bit pattern) independent of ghost headroom.  The streaming
            # layer appends them lazily on its copy-on-write partition
            # (blocks.complete_forest) before any weight-driven coverage
            # math, so no hole survives an insert into a ghost subtree.
            if w[ai] > 0 and w[bc] > 0:
                new_a.append(ai)
                new_b.append(bc)
        bp.active[i] = False
        bp.refined[i] = True
        if stale is not None and i < len(stale):
            stale[i] = False

    # refinement children generally have no mirror in B (the paper's
    # "if it also belongs to B" clause) — only coarsest sibling blocks do.
    bp.append_pairs(
        np.asarray(new_a, np.int32),
        np.asarray(new_b, np.int32),
        np.full(len(new_a), -1, np.int32),
    )
    return len(picked)


def refine_to_budget(
    bp: BlockPartition,
    tree: PartitionTree,
    sigma: jax.Array,
    max_blocks: int,
    batch: int = 64,
    refit_sigma: bool = False,
    divergence=None,
    stale: np.ndarray | None = None,
) -> Tuple[QState, jax.Array]:
    """Refine until ``n_active >= max_blocks``; returns final (QState, sigma).

    Re-optimizes q globally after every batched round (the paper re-optimizes
    after every single refinement; batching amortizes this — measured in
    benchmarks/refinement.py).

    ``stale`` (optional bool array over block slots) prioritizes blocks
    whose stats were patched by streaming mutations — see
    :func:`refine_topk`; refined slots are cleared in place so a streaming
    model's staleness bookkeeping drains as the budget is spent.
    """
    from repro.core.divergence import bind_divergence
    from repro.core.sigma import sigma_star  # local import to avoid cycle

    div = bind_divergence(divergence, tree)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), sigma, divergence=div)
    while bp.n_active < max_blocks:
        k = min(batch, max(1, (max_blocks - bp.n_active) // 2))
        gains = refinement_gains(
            tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active),
            qs.log_q, sigma, divergence=div,
        )
        done = refine_topk(bp, tree, np.asarray(gains), k, stale=stale)
        if done == 0:
            break
        qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                        jnp.asarray(bp.active), sigma, divergence=div)
        if refit_sigma:
            sigma = sigma_star(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                               jnp.asarray(bp.active), qs.log_q, divergence=div)
            qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                            jnp.asarray(bp.active), sigma, divergence=div)
    return qs, sigma
