"""Core library: the paper's contribution — variational dual-tree transition
matrix approximation, O(|B|) random-walk inference, bandwidth learning,
greedy refinement — plus the exact / kNN baselines it is compared against.
"""
from repro.core.baselines import (
    build_knn_graph,
    exact_transition_matrix,
    knn_matvec,
    streaming_exact_matvec,
)
from repro.core.blocks import (
    BlockPartition,
    coarsest_partition,
    complete_forest,
    refresh_active,
    validate_partition,
)
from repro.core.divergence import (
    DIVERGENCES,
    Divergence,
    get_divergence,
    mahalanobis,
    register_divergence,
    resolve_divergence,
)
from repro.core.label_prop import (
    ccr,
    label_propagate,
    one_hot_labels,
    route_backend,
)
from repro.core.matvec import mpt_matvec
from repro.core.qopt import QState, optimize_q
from repro.core.refine import refine_to_budget, refinement_gains
from repro.core.sigma import fit_sigma_q, sigma_init, sigma_star
from repro.core.streaming import (
    CapacityError,
    StreamUpdate,
    delete_points,
    insert_points,
)
from repro.core.tree import PartitionTree, build_tree
from repro.core.vdt import VariationalDualTree

__all__ = [
    "BlockPartition",
    "CapacityError",
    "DIVERGENCES",
    "Divergence",
    "PartitionTree",
    "QState",
    "StreamUpdate",
    "VariationalDualTree",
    "build_knn_graph",
    "build_tree",
    "ccr",
    "coarsest_partition",
    "complete_forest",
    "delete_points",
    "exact_transition_matrix",
    "fit_sigma_q",
    "get_divergence",
    "insert_points",
    "knn_matvec",
    "mahalanobis",
    "label_propagate",
    "mpt_matvec",
    "one_hot_labels",
    "optimize_q",
    "refine_to_budget",
    "refinement_gains",
    "refresh_active",
    "register_divergence",
    "resolve_divergence",
    "route_backend",
    "sigma_init",
    "sigma_star",
    "streaming_exact_matvec",
    "validate_partition",
]
