"""Balanced binary partition tree in heap layout.

TPU adaptation of the paper's anchor tree (Moore, 2000): instead of a
pointer-based tree built by triangle-inequality pruning, we build a perfectly
balanced binary tree by recursive median splits along the locally dominant
direction.  The tree is stored in *heap layout*:

  - node ids are flat ints; the root is 0, children of node ``k`` are
    ``2k+1`` and ``2k+2``;
  - level ``l`` occupies ids ``[2^l - 1, 2^{l+1} - 1)``;
  - leaves live at level ``L`` (ids ``Np-1 .. 2*Np-2``) where ``Np = 2^L``;
  - node ``k`` at level ``l`` covers the *contiguous* leaf-slot range
    ``[(k - (2^l - 1)) * 2^(L-l), ...)`` — contiguity is what makes every
    downstream operation (stats, q-optimization, matvec) a dense
    reshape/segment op instead of pointer chasing.

Arbitrary N is supported by padding to ``Np = 2^L`` with zero-weight *ghost*
leaves.  All node statistics are weighted (``W(A) = sum_i w_i``,
``S1(A) = sum_i w_i x_i``, ``S2(A) = sum_i w_i ||x_i||^2``) so the paper's
factorization (eq. 9) holds verbatim with ``|A| -> W(A)`` and ghosts provably
carry zero probability mass.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PartitionTree",
    "build_tree",
    "node_level",
    "leaf_range",
    "level_slice",
]

_GHOST_PROJ = 1e30  # ghosts sort to the right end of every segment


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionTree:
    """Heap-layout balanced partition tree with weighted subtree statistics."""

    # static metadata
    L: int = dataclasses.field(metadata=dict(static=True))
    n_points: int = dataclasses.field(metadata=dict(static=True))
    dim: int = dataclasses.field(metadata=dict(static=True))

    # leaf-order data
    x_leaf: jax.Array  # (Np, d)   points permuted into leaf order (ghosts 0)
    w_leaf: jax.Array  # (Np,)     weights in leaf order (ghosts 0)
    slot_of: jax.Array  # (N,)     original row -> leaf slot
    leaf_of: jax.Array  # (Np,)    leaf slot -> original row (ghosts -> N)

    # flat per-node statistics, heap indexed, shape (n_nodes, ...)
    W: jax.Array   # (n_nodes,)    weighted counts
    S1: jax.Array  # (n_nodes, d)  weighted coordinate sums
    S2: jax.Array  # (n_nodes,)    weighted squared-norm sums

    @property
    def n_leaves(self) -> int:
        return 1 << self.L

    @property
    def n_nodes(self) -> int:
        return (1 << (self.L + 1)) - 1

    @property
    def n_internal(self) -> int:
        return (1 << self.L) - 1

    @property
    def total_weight(self) -> jax.Array:
        return self.W[0]


def node_level(node_id: np.ndarray) -> np.ndarray:
    """Level of a heap node id (root = 0)."""
    return np.floor(np.log2(np.asarray(node_id) + 1)).astype(np.int64)


def level_slice(level: int) -> slice:
    """Flat id range occupied by ``level``."""
    return slice((1 << level) - 1, (1 << (level + 1)) - 1)


def leaf_range(node_id: int, L: int) -> tuple[int, int]:
    """Contiguous leaf-slot range [lo, hi) covered by ``node_id``."""
    lvl = int(node_level(node_id))
    idx = node_id - ((1 << lvl) - 1)
    span = 1 << (L - lvl)
    return idx * span, (idx + 1) * span


def _principal_projection(xs: jax.Array, ws: jax.Array, iters: int) -> jax.Array:
    """Projection of each point on the dominant covariance direction.

    xs: (segments, s, d), ws: (segments, s).  Power iteration on the weighted
    covariance, never materializing the (d, d) matrix.  Deterministic init.
    Returns (segments, s) projections.
    """
    tot = jnp.maximum(ws.sum(axis=1, keepdims=True), 1e-12)
    mean = (xs * ws[..., None]).sum(axis=1, keepdims=True) / tot[..., None]
    a = (xs - mean) * jnp.sqrt(ws)[..., None]  # (seg, s, d); rows of sqrt(w)(x-mu)

    d = xs.shape[-1]
    # deterministic, slightly asymmetric init to avoid pathological symmetry
    v = jnp.ones((xs.shape[0], d)) + 1e-3 * jnp.arange(d, dtype=xs.dtype)
    v = v / jnp.linalg.norm(v, axis=-1, keepdims=True)

    def body(v, _):
        u = jnp.einsum("bsd,bd->bs", a, v)
        v = jnp.einsum("bsd,bs->bd", a, u)
        n = jnp.linalg.norm(v, axis=-1, keepdims=True)
        v = jnp.where(n > 1e-12, v / jnp.maximum(n, 1e-12), v * 0 + 1.0 / math.sqrt(d))
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    return jnp.einsum("bsd,bd->bs", xs - mean, v)


@functools.partial(jax.jit, static_argnames=("L", "power_iters"))
def _build_impl(xp: jax.Array, wp: jax.Array, L: int, power_iters: int):
    Np, d = xp.shape
    order = jnp.arange(Np)

    for lvl in range(L):
        seg, s = 1 << lvl, Np >> lvl
        xs = xp[order].reshape(seg, s, d)
        ws = wp[order].reshape(seg, s)
        proj = _principal_projection(xs, ws, power_iters)
        proj = jnp.where(ws > 0, proj, _GHOST_PROJ)  # ghosts go right
        idx = jnp.argsort(proj, axis=1)
        order = jnp.take_along_axis(order.reshape(seg, s), idx, axis=1).reshape(-1)

    x_leaf = xp[order]
    w_leaf = wp[order]

    # bottom-up weighted statistics, level-major then flat-concatenated
    Ws = [w_leaf]
    S1s = [x_leaf * w_leaf[:, None]]
    S2s = [(x_leaf * x_leaf).sum(-1) * w_leaf]
    for lvl in range(L - 1, -1, -1):
        Ws.append(Ws[-1].reshape(-1, 2).sum(1))
        S1s.append(S1s[-1].reshape(-1, 2, d).sum(1))
        S2s.append(S2s[-1].reshape(-1, 2).sum(1))
    W = jnp.concatenate(Ws[::-1])
    S1 = jnp.concatenate(S1s[::-1])
    S2 = jnp.concatenate(S2s[::-1])
    return order, x_leaf, w_leaf, W, S1, S2


def build_tree(
    x: jax.Array,
    weights: Optional[jax.Array] = None,
    power_iters: int = 8,
    capacity: Optional[int] = None,
) -> PartitionTree:
    """Build the shared partition tree over data points ``x`` (N, d).

    ``capacity`` (>= N) sizes the leaf level for at least that many points,
    leaving ``2^L - N`` zero-weight ghost leaves as insertion headroom for
    online updates (``core/streaming.py``).  The default sizes for N alone
    — ghost slots then only exist from the power-of-two rounding.
    """
    x = jnp.asarray(x, dtype=jnp.float32)
    n, d = x.shape
    if weights is None:
        weights = jnp.ones((n,), dtype=x.dtype)
    weights = jnp.asarray(weights, dtype=x.dtype)

    if capacity is not None and capacity < n:
        raise ValueError(f"capacity={capacity} < n_points={n}")
    L = max(1, math.ceil(math.log2(max(n, capacity or 0, 2))))
    np_ = 1 << L
    xp = jnp.pad(x, ((0, np_ - n), (0, 0)))
    wp = jnp.pad(weights, (0, np_ - n))

    order, x_leaf, w_leaf, W, S1, S2 = _build_impl(xp, wp, L, power_iters)

    leaf_of = jnp.where(order < n, order, n)
    # ghost leaves all scatter into the sacrificial slot ``n`` which is dropped
    slot_of = (
        jnp.full((n + 1,), -1, dtype=jnp.int32)
        .at[leaf_of]
        .set(jnp.arange(np_, dtype=jnp.int32))[:n]
    )

    return PartitionTree(
        L=L,
        n_points=n,
        dim=d,
        x_leaf=x_leaf,
        w_leaf=w_leaf,
        slot_of=slot_of,
        leaf_of=leaf_of,
        W=W,
        S1=S1,
        S2=S2,
    )
