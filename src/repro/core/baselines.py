"""Baselines from the paper's §5.1: the exact model and the kNN graph.

* ``exact``  — dense row-softmax transition matrix (eq. 3, zero diagonal).
  Also a streaming matvec form that never materializes P (see
  kernels/fused_lp for the Pallas version; here a blocked jnp fallback).
* ``knn``    — each point keeps its k nearest neighbours; edge weights from
  eq. 3 restricted to those k.  TPU adaptation: blocked brute-force
  distances + top_k on the MXU instead of kd/anchor-tree search.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "exact_transition_matrix",
    "exact_matvec",
    "streaming_exact_matvec",
    "KnnGraph",
    "build_knn_graph",
    "knn_matvec",
]


def _sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """(n, m) pairwise squared distances, MXU-friendly (x@y.T + norms)."""
    xn = (x * x).sum(-1)
    yn = (y * y).sum(-1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


@jax.jit
def exact_transition_matrix(x: jax.Array, sigma: jax.Array) -> jax.Array:
    """Dense P via eq. 3: row softmax of -d^2/(2 sigma^2), zero diagonal."""
    n = x.shape[0]
    logits = -_sq_dists(x, x) / (2.0 * sigma * sigma)
    logits = jnp.where(jnp.eye(n, dtype=bool), -jnp.inf, logits)
    return jax.nn.softmax(logits, axis=-1)


@jax.jit
def exact_matvec(p: jax.Array, y: jax.Array) -> jax.Array:
    return p @ y


@functools.partial(jax.jit, static_argnames=("block",))
def streaming_exact_matvec(
    x: jax.Array, y: jax.Array, sigma: jax.Array, block: int = 1024
) -> jax.Array:
    """P @ Y without materializing P: online-softmax over column tiles.

    O(N^2 d) FLOPs, O(N * block) memory.  jnp reference implementation of the
    fused_lp Pallas kernel (kernels/fused_lp/ref.py re-exports this).
    """
    n, d = x.shape
    c = y.shape[1]
    nb = -(-n // block)
    npad = nb * block
    xp = jnp.pad(x, ((0, npad - n), (0, 0)))
    yp = jnp.pad(y, ((0, npad - n), (0, 0)))
    valid = jnp.arange(npad) < n
    inv = 1.0 / (2.0 * sigma * sigma)
    xn = (x * x).sum(-1)

    def body(carry, j):
        m, s, acc = carry  # running max (n,), normalizer (n,), weighted sum (n, c)
        xb = jax.lax.dynamic_slice_in_dim(xp, j * block, block)
        yb = jax.lax.dynamic_slice_in_dim(yp, j * block, block)
        vb = jax.lax.dynamic_slice_in_dim(valid, j * block, block)
        d2 = xn[:, None] + (xb * xb).sum(-1)[None, :] - 2.0 * (x @ xb.T)
        logits = -jnp.maximum(d2, 0.0) * inv
        col = j * block + jnp.arange(block)
        diag_or_pad = (col[None, :] == jnp.arange(n)[:, None]) | ~vb[None, :]
        logits = jnp.where(diag_or_pad, -jnp.inf, logits)
        bm = logits.max(axis=1)
        new_m = jnp.maximum(m, bm)
        scale = jnp.exp(m - new_m)
        p = jnp.exp(logits - new_m[:, None])
        s = s * scale + p.sum(axis=1)
        acc = acc * scale[:, None] + p @ yb
        return (new_m, s, acc), None

    init = (
        jnp.full((n,), -jnp.inf, x.dtype),
        jnp.zeros((n,), x.dtype),
        jnp.zeros((n, c), x.dtype),
    )
    (m, s, acc), _ = jax.lax.scan(body, init, jnp.arange(nb))
    del m, d
    return acc / jnp.maximum(s, 1e-38)[:, None]


class KnnGraph(NamedTuple):
    indices: jax.Array  # (N, k) neighbour ids
    weights: jax.Array  # (N, k) row-normalized transition probabilities


@functools.partial(jax.jit, static_argnames=("k", "block"))
def build_knn_graph(
    x: jax.Array, k: int, sigma: jax.Array, block: int = 2048
) -> KnnGraph:
    """Blocked brute-force kNN + eq. 3 weights restricted to the k edges."""
    n = x.shape[0]
    nb = -(-n // block)
    npad = nb * block
    xp = jnp.pad(x, ((0, npad - n), (0, 0)))

    def row_block(i):
        xb = jax.lax.dynamic_slice_in_dim(xp, i * block, block)
        d2 = _sq_dists(xb, x)  # (block, n)
        rows = i * block + jnp.arange(block)
        d2 = jnp.where(rows[:, None] == jnp.arange(n)[None, :], jnp.inf, d2)
        neg, idx = jax.lax.top_k(-d2, k)
        return idx, -neg

    idx, d2 = jax.lax.map(row_block, jnp.arange(nb))
    idx = idx.reshape(npad, k)[:n]
    d2 = d2.reshape(npad, k)[:n]
    logits = -d2 / (2.0 * sigma * sigma)
    w = jax.nn.softmax(logits, axis=-1)
    return KnnGraph(indices=idx, weights=w)


@jax.jit
def knn_matvec(g: KnnGraph, y: jax.Array) -> jax.Array:
    """O(kN) sparse matvec: (PY)_i = sum_k w_ik y_{idx_ik}."""
    return jnp.einsum("nk,nkc->nc", g.weights, y[g.indices])
