"""Block partitions of the transition matrix over the shared partition tree.

A *block* ``(A, B)`` ties together all matrix entries ``P[i, j]`` with data
point ``x_i`` in subtree ``A`` and kernel ``m_j`` in subtree ``B`` (paper
§3.1).  A valid partition covers every off-diagonal entry exactly once; the
coarsest valid partition consists of both orderings of every sibling pair —
``|B_c| = 2(Np - 1)`` blocks (paper §4.4).

Bookkeeping (append/deactivate during refinement) is host-side numpy with
preallocated capacity; all numeric work (q-optimization, gains, matvec) runs
on padded device arrays masked by ``active``, so each capacity compiles once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.tree import PartitionTree, leaf_range, node_level

__all__ = ["BlockPartition", "coarsest_partition", "densify_q", "validate_partition"]


@dataclasses.dataclass
class BlockPartition:
    """Flat block arrays with capacity ``cap`` and ``n`` live entries."""

    a: np.ndarray        # (cap,) int32 data-subtree node id
    b: np.ndarray        # (cap,) int32 kernel-subtree node id
    mirror: np.ndarray   # (cap,) int32 index of the (b, a) block
    active: np.ndarray   # (cap,) bool
    n: int               # high-water mark (slots [0, n) ever used)
    cap: int

    @property
    def n_active(self) -> int:
        return int(self.active[: self.n].sum())

    def grow_to(self, new_cap: int) -> "BlockPartition":
        if new_cap <= self.cap:
            return self
        pad = new_cap - self.cap
        return BlockPartition(
            a=np.concatenate([self.a, np.zeros(pad, np.int32)]),
            b=np.concatenate([self.b, np.zeros(pad, np.int32)]),
            mirror=np.concatenate([self.mirror, np.full(pad, -1, np.int32)]),
            active=np.concatenate([self.active, np.zeros(pad, bool)]),
            n=self.n,
            cap=new_cap,
        )

    def append_pairs(self, a_new: np.ndarray, b_new: np.ndarray,
                     mirror_new: np.ndarray) -> np.ndarray:
        """Append blocks; returns their indices.  Grows capacity if needed."""
        k = len(a_new)
        if self.n + k > self.cap:
            grown = self.grow_to(max(self.cap * 2, self.n + k))
            self.__dict__.update(grown.__dict__)
        idx = np.arange(self.n, self.n + k)
        self.a[idx] = a_new
        self.b[idx] = b_new
        self.mirror[idx] = mirror_new
        self.active[idx] = True
        self.n += k
        return idx


def coarsest_partition(tree: PartitionTree, cap: int | None = None) -> BlockPartition:
    """Both orderings of every sibling pair: ``|B_c| = 2(Np - 1)`` blocks.

    Blocks whose data or kernel side is all-ghost (W == 0) are created
    inactive — they carry no probability mass and never refine.
    """
    n_int = tree.n_internal
    n0 = 2 * n_int
    cap = int(cap if cap is not None else max(2 * n0, 64))
    bp = BlockPartition(
        a=np.zeros(cap, np.int32),
        b=np.zeros(cap, np.int32),
        mirror=np.full(cap, -1, np.int32),
        active=np.zeros(cap, bool),
        n=n0,
        cap=cap,
    )
    k = np.arange(n_int, dtype=np.int32)
    bp.a[0:n0:2] = 2 * k + 1
    bp.b[0:n0:2] = 2 * k + 2
    bp.a[1:n0:2] = 2 * k + 2
    bp.b[1:n0:2] = 2 * k + 1
    bp.mirror[0:n0:2] = 2 * k + 1
    bp.mirror[1:n0:2] = 2 * k
    w = np.asarray(tree.W)
    bp.active[:n0] = (w[bp.a[:n0]] > 0) & (w[bp.b[:n0]] > 0)
    return bp


def validate_partition(bp: BlockPartition, tree: PartitionTree) -> bool:
    """Partition validity (paper §3.1), checked on real leaves:

    - every off-diagonal pair of *real* leaves is covered by exactly one
      active block (ghost leaves carry zero weight — their coverage is
      irrelevant since their mass is provably zero), and
    - no diagonal entry is ever covered (blocks have ``A ∩ B = ∅``).
    """
    real = np.asarray(tree.w_leaf) > 0
    cover = np.zeros((tree.n_leaves, tree.n_leaves), dtype=np.int32)
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        alo, ahi = leaf_range(int(bp.a[i]), tree.L)
        blo, bhi = leaf_range(int(bp.b[i]), tree.L)
        cover[alo:ahi, blo:bhi] += 1
    if np.any(np.diagonal(cover) != 0):
        return False
    rr = np.ix_(real, real)
    want = 1 - np.eye(int(real.sum()), dtype=np.int32)
    return bool(np.all(cover[rr] == want))


def densify_q(bp: BlockPartition, tree: PartitionTree, q: np.ndarray) -> np.ndarray:
    """Expand block parameters into the dense (N, N) matrix Q (tests only)."""
    n = tree.n_points
    slot = np.asarray(tree.slot_of)
    dense = np.zeros((tree.n_leaves, tree.n_leaves), dtype=np.float64)
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        alo, ahi = leaf_range(int(bp.a[i]), tree.L)
        blo, bhi = leaf_range(int(bp.b[i]), tree.L)
        dense[alo:ahi, blo:bhi] = q[i]
    out = np.zeros((n, n), dtype=np.float64)
    out[:, :] = dense[np.ix_(slot, slot)]
    np.fill_diagonal(out, 0.0)
    return out


def mirror_invariant_ok(bp: BlockPartition) -> bool:
    """Mirror indices must be mutual and swap (a, b)."""
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        m = int(bp.mirror[i])
        if m < 0:
            continue
        if not bp.active[m]:
            return False
        if bp.mirror[m] != i or bp.a[m] != bp.b[i] or bp.b[m] != bp.a[i]:
            return False
    return True


def levels_of(bp: BlockPartition) -> np.ndarray:
    """Per-block (a-level, b-level) for diagnostics."""
    return np.stack([node_level(bp.a[: bp.n]), node_level(bp.b[: bp.n])], axis=1)
