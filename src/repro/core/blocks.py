"""Block partitions of the transition matrix over the shared partition tree.

A *block* ``(A, B)`` ties together all matrix entries ``P[i, j]`` with data
point ``x_i`` in subtree ``A`` and kernel ``m_j`` in subtree ``B`` (paper
§3.1).  A valid partition covers every off-diagonal entry exactly once; the
coarsest valid partition consists of both orderings of every sibling pair —
``|B_c| = 2(Np - 1)`` blocks (paper §4.4).

Bookkeeping (append/deactivate during refinement) is host-side numpy with
preallocated capacity; all numeric work (q-optimization, gains, matvec) runs
on padded device arrays masked by ``active``, so each capacity compiles once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.tree import PartitionTree, leaf_range, node_level

__all__ = ["BlockPartition", "coarsest_partition", "complete_forest",
           "densify_q", "refresh_active", "validate_partition"]


@dataclasses.dataclass
class BlockPartition:
    """Flat block arrays with capacity ``cap`` and ``n`` live entries."""

    a: np.ndarray        # (cap,) int32 data-subtree node id
    b: np.ndarray        # (cap,) int32 kernel-subtree node id
    mirror: np.ndarray   # (cap,) int32 index of the (b, a) block
    active: np.ndarray   # (cap,) bool
    n: int               # high-water mark (slots [0, n) ever used)
    cap: int
    # which slots were split into their horizontal children.  With it,
    # activity is a pure function of the tree's weights: a slot covers real
    # mass iff it is an unrefined forest leaf with both sides non-ghost, so
    # ``refresh_active`` can recompute coverage after the streaming layer
    # patches subtree weights (insert into a formerly all-ghost subtree);
    # ``complete_forest`` restores children the fit dropped as all-ghost.
    refined: np.ndarray = None

    def __post_init__(self):
        if self.refined is None:
            self.refined = np.zeros(self.cap, bool)

    @property
    def n_active(self) -> int:
        return int(self.active[: self.n].sum())

    def grow_to(self, new_cap: int) -> "BlockPartition":
        if new_cap <= self.cap:
            return self
        pad = new_cap - self.cap
        return BlockPartition(
            a=np.concatenate([self.a, np.zeros(pad, np.int32)]),
            b=np.concatenate([self.b, np.zeros(pad, np.int32)]),
            mirror=np.concatenate([self.mirror, np.full(pad, -1, np.int32)]),
            active=np.concatenate([self.active, np.zeros(pad, bool)]),
            n=self.n,
            cap=new_cap,
            refined=np.concatenate([self.refined, np.zeros(pad, bool)]),
        )

    def append_pairs(self, a_new: np.ndarray, b_new: np.ndarray,
                     mirror_new: np.ndarray,
                     active_new: np.ndarray | None = None) -> np.ndarray:
        """Append blocks; returns their indices.  Grows capacity if needed.

        ``active_new`` marks which appended blocks carry real mass right
        now (default: all) — :func:`complete_forest` uses it to append
        ghost-sided refinement children inactive.
        """
        k = len(a_new)
        if self.n + k > self.cap:
            grown = self.grow_to(max(self.cap * 2, self.n + k))
            self.__dict__.update(grown.__dict__)
        idx = np.arange(self.n, self.n + k)
        self.a[idx] = a_new
        self.b[idx] = b_new
        self.mirror[idx] = mirror_new
        self.active[idx] = True if active_new is None else active_new
        self.refined[idx] = False
        self.n += k
        return idx


def coarsest_partition(tree: PartitionTree, cap: int | None = None) -> BlockPartition:
    """Both orderings of every sibling pair: ``|B_c| = 2(Np - 1)`` blocks.

    Blocks whose data or kernel side is all-ghost (W == 0) are created
    inactive — they carry no probability mass and never refine.
    """
    n_int = tree.n_internal
    n0 = 2 * n_int
    cap = int(cap if cap is not None else max(2 * n0, 64))
    bp = BlockPartition(
        a=np.zeros(cap, np.int32),
        b=np.zeros(cap, np.int32),
        mirror=np.full(cap, -1, np.int32),
        active=np.zeros(cap, bool),
        n=n0,
        cap=cap,
    )
    k = np.arange(n_int, dtype=np.int32)
    bp.a[0:n0:2] = 2 * k + 1
    bp.b[0:n0:2] = 2 * k + 2
    bp.a[1:n0:2] = 2 * k + 2
    bp.b[1:n0:2] = 2 * k + 1
    bp.mirror[0:n0:2] = 2 * k + 1
    bp.mirror[1:n0:2] = 2 * k
    w = np.asarray(tree.W)
    bp.active[:n0] = (w[bp.a[:n0]] > 0) & (w[bp.b[:n0]] > 0)
    return bp


def complete_forest(bp: BlockPartition) -> BlockPartition:
    """Copy ``bp`` with every refined slot's missing children restored.

    ``refine_topk`` drops a refined block's child when its kernel side is
    all-ghost: the child covers no real pair at fit time, and appending it
    would make the fitted block layout depend on ghost headroom.  Streaming
    mutations can later put mass INTO such a subtree, so before any
    weight-driven coverage math (:func:`refresh_active`) the streaming
    layer appends the missing children here — inactive, with no mirror
    (refinement children never have one).  Always returns a fresh
    copy-on-write partition; on an already-complete forest the copy simply
    has nothing appended, so repeated calls converge after the first.
    """
    n = bp.n
    have = set(zip(bp.a[:n].tolist(), bp.b[:n].tolist()))
    miss_a, miss_b = [], []
    for i in np.flatnonzero(bp.refined[:n]):
        ai, bi = int(bp.a[i]), int(bp.b[i])
        for bc in (2 * bi + 1, 2 * bi + 2):
            if (ai, bc) not in have:
                miss_a.append(ai)
                miss_b.append(bc)
    out = BlockPartition(
        a=bp.a.copy(), b=bp.b.copy(), mirror=bp.mirror.copy(),
        active=bp.active.copy(), n=bp.n, cap=bp.cap,
        refined=bp.refined.copy())
    if miss_a:
        out.append_pairs(
            np.asarray(miss_a, np.int32), np.asarray(miss_b, np.int32),
            np.full(len(miss_a), -1, np.int32),
            active_new=np.zeros(len(miss_a), bool))
    return out


def refresh_active(bp: BlockPartition, W: np.ndarray) -> np.ndarray:
    """Recompute ``active`` from per-node weights ``W`` (streaming updates).

    Requires a *complete* refinement forest over the coarsest sibling
    pairs (see :func:`complete_forest`): every refined slot is present
    alongside both of its horizontal children, so the unrefined slots tile
    every off-diagonal leaf pair exactly once geometrically.  A real pair
    (i, j) therefore lies in exactly one unrefined slot, and that slot has
    W > 0 on both sides — so ``active = ~refined & (W[a] > 0) & (W[b] > 0)``
    is the unique correct coverage for ANY weight vector, including ones
    produced by online insert/delete after the partition was built.
    Returns the new (cap,) active array without mutating ``bp``.
    """
    W = np.asarray(W)
    n = bp.n
    active = np.zeros(bp.cap, bool)
    active[:n] = (~bp.refined[:n]) & (W[bp.a[:n]] > 0) & (W[bp.b[:n]] > 0)
    return active


def validate_partition(bp: BlockPartition, tree: PartitionTree) -> bool:
    """Partition validity (paper §3.1), checked on real leaves:

    - every off-diagonal pair of *real* leaves is covered by exactly one
      active block (ghost leaves carry zero weight — their coverage is
      irrelevant since their mass is provably zero), and
    - no diagonal entry is ever covered (blocks have ``A ∩ B = ∅``).
    """
    real = np.asarray(tree.w_leaf) > 0
    cover = np.zeros((tree.n_leaves, tree.n_leaves), dtype=np.int32)
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        alo, ahi = leaf_range(int(bp.a[i]), tree.L)
        blo, bhi = leaf_range(int(bp.b[i]), tree.L)
        cover[alo:ahi, blo:bhi] += 1
    if np.any(np.diagonal(cover) != 0):
        return False
    rr = np.ix_(real, real)
    want = 1 - np.eye(int(real.sum()), dtype=np.int32)
    return bool(np.all(cover[rr] == want))


def densify_q(bp: BlockPartition, tree: PartitionTree, q: np.ndarray) -> np.ndarray:
    """Expand block parameters into the dense (N, N) matrix Q (tests only)."""
    n = tree.n_points
    slot = np.asarray(tree.slot_of)
    dense = np.zeros((tree.n_leaves, tree.n_leaves), dtype=np.float64)
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        alo, ahi = leaf_range(int(bp.a[i]), tree.L)
        blo, bhi = leaf_range(int(bp.b[i]), tree.L)
        dense[alo:ahi, blo:bhi] = q[i]
    out = np.zeros((n, n), dtype=np.float64)
    out[:, :] = dense[np.ix_(slot, slot)]
    np.fill_diagonal(out, 0.0)
    return out


def mirror_invariant_ok(bp: BlockPartition) -> bool:
    """Mirror indices must be mutual and swap (a, b)."""
    for i in range(bp.n):
        if not bp.active[i]:
            continue
        m = int(bp.mirror[i])
        if m < 0:
            continue
        if not bp.active[m]:
            return False
        if bp.mirror[m] != i or bp.a[m] != bp.b[i] or bp.b[m] != bp.a[i]:
            return False
    return True


def levels_of(bp: BlockPartition) -> np.ndarray:
    """Per-block (a-level, b-level) for diagnostics."""
    return np.stack([node_level(bp.a[: bp.n]), node_level(bp.b[: bp.n])], axis=1)
