"""Bandwidth learning for the Gaussian similarity kernel (paper §4.2).

Two estimators:

  * ``sigma_init``  — the refined-limit closed form (eq. 14), computed
    exactly in O(N d) via the moment identity
    ``sum_{ij} w_i w_j ||x_i - x_j||^2 = 2 W sum_i w_i||x_i||^2 - 2||sum_i w_i x_i||^2``.
  * ``sigma_star``  — the block closed form (eq. 12) given current q,
    ``sigma*^2 = sum_B q_AB D2_AB / (d * W)``.

``fit_sigma_q`` alternates q-optimization and eq. 12 until relative change
in sigma falls below tolerance (paper: "convergence ... is fast and not
sensitive to the initial value").
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.qopt import QState, block_sq_dists, optimize_q
from repro.core.tree import PartitionTree

__all__ = ["sigma_init", "sigma_star", "fit_sigma_q"]


def sigma_init(x: jax.Array, weights: jax.Array | None = None) -> jax.Array:
    """Eq. (14) via exact O(N d) moments."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    w = jnp.ones((n,), x.dtype) if weights is None else jnp.asarray(weights, x.dtype)
    w_tot = w.sum()
    s1 = (x * w[:, None]).sum(0)
    s2 = ((x * x).sum(-1) * w).sum()
    sum_sq = 2.0 * w_tot * s2 - 2.0 * (s1 * s1).sum()
    return jnp.sqrt(jnp.maximum(sum_sq, 1e-12) / d) / jnp.maximum(w_tot, 1.0)


def sigma_star(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    log_q: jax.Array,
    divergence=None,
) -> jax.Array:
    """Eq. (12): closed-form optimal bandwidth given fixed q.

    With a non-default ``divergence`` the numerator sums the block Bregman
    divergences instead of squared distances — the same stationarity
    condition of the generalized bound in ``sigma``.
    """
    q = jnp.where(active & jnp.isfinite(log_q), jnp.exp(log_q), 0.0)
    d2 = block_sq_dists(tree, a, b, divergence=divergence)
    num = (q * d2).sum()
    return jnp.sqrt(jnp.maximum(num, 1e-12) / (tree.dim * jnp.maximum(tree.W[0], 1.0)))


def fit_sigma_q(
    tree: PartitionTree,
    a: jax.Array,
    b: jax.Array,
    active: jax.Array,
    sigma0: jax.Array | float,
    max_iters: int = 20,
    tol: float = 1e-3,
    divergence=None,
) -> Tuple[jax.Array, QState, int]:
    """Alternate eq. (7) q-optimization with eq. (12) until convergence."""
    from repro.core.divergence import bind_divergence

    div = bind_divergence(divergence, tree)  # bind stats once, reuse per iter
    sigma = jnp.asarray(sigma0, jnp.float32)
    qs = optimize_q(tree, a, b, active, sigma, divergence=div)
    it = 0
    for it in range(1, max_iters + 1):
        new_sigma = sigma_star(tree, a, b, active, qs.log_q, divergence=div)
        rel = abs(float(new_sigma) - float(sigma)) / max(float(sigma), 1e-12)
        sigma = new_sigma
        qs = optimize_q(tree, a, b, active, sigma, divergence=div)
        if rel < tol:
            break
    return sigma, qs, it
