"""paper-vdt — the paper's own workload as a dry-run cell: distributed
Label-Propagation step over a variational dual-tree transition matrix.

N = 2^18 points (~ half the Table-2 'alpha' run), C = 8 classes, |B| = 4N blocks
(the paper's kNN-equivalence point k = |B|/N = 4).
"""
from repro.core.distributed import vdt_input_specs

NAME = "paper-vdt"
N_POINTS = 1 << 18
N_CLASSES = 8
BLOCKS_PER_POINT = 4
ALPHA = 0.01


def input_specs():
    return vdt_input_specs(N_POINTS, N_CLASSES, BLOCKS_PER_POINT)
