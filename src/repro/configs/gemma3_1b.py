"""gemma3-1b [dense] — 5:1 local:global sliding-window GQA, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab_size=262_144, head_dim=256,
    sliding_window=1024, local_global_ratio=5, rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=16, sliding_window=8, local_global_ratio=2,
    remat=False,
)
