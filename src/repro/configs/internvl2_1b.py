"""internvl2-1b [vlm] — InternViT patch stub + qwen2-style LM backbone.
[arXiv:2404.16821; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151_655, n_patches=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=112,
    vocab_size=512, n_patches=8, remat=False,
)
