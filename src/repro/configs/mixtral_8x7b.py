"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab_size=32_000,
    n_experts=8, experts_per_token=2, sliding_window=4096,
    expert_parallel=False,   # 8 experts < 16-way model axis -> expert TP
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, n_experts=4, experts_per_token=2, sliding_window=16,
    remat=False, capacity_factor=4.0,
)
