"""Assigned input-shape sets and ShapeDtypeStruct input specs per cell.

Shapes (LM family — seq_len x global_batch):
  train_4k     seq=4096    batch=256   -> train_step
  prefill_32k  seq=32768   batch=32    -> serve prefill
  decode_32k   seq=32768   batch=128   -> serve_step (1 token, cache=seq)
  long_500k    seq=524288  batch=1     -> serve_step; requires sub-quadratic
                                          sequence mixing (SSM/hybrid/SWA)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "cell_is_applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            f"{cfg.name} is a full-attention arch; 500k decode requires "
            "sub-quadratic sequence mixing — skipped per assignment rules"
        )
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape: ShapeSpec, batch_override: Optional[int] = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (kwargs dict for the step function, metadata).  Frontends for
    vlm/audio are stubs: precomputed patch/frame embeddings.
    """
    b = batch_override or shape.global_batch
    s = shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32

    extras = {}
    if cfg.family == "vlm":
        text = s - cfg.n_patches
        extras["patches"] = _sds((b, cfg.n_patches, cfg.d_model), f32)
    else:
        text = s
    if cfg.family == "audio":
        extras["frames"] = _sds((b, cfg.encoder_frames, cfg.d_model), f32)

    if shape.kind == "train":
        batch = {"tokens": _sds((b, text + 1), i32), **extras}
        return {"batch": batch}, {"tokens_per_step": b * s}
    if shape.kind == "prefill":
        return (
        {"tokens": _sds((b, text), i32), **extras},
            {"tokens_per_step": b * s},
        )
    # decode: one token against a cache of length s
    from repro.serving.decode import DECODE_SLACK, init_state

    state = jax.eval_shape(lambda: init_state(cfg, b, s + DECODE_SLACK))
    return (
        {"token": _sds((b, 1), i32), "state": state},
        {"tokens_per_step": b},
    )
