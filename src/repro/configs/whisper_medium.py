"""whisper-medium [audio] — enc-dec backbone; conv/mel frontend is a stub
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51_865,
    is_encoder_decoder=True, n_encoder_layers=24, encoder_frames=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, n_encoder_layers=2, encoder_frames=16, remat=False,
)
