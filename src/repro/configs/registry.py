"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config"]

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "internlm2-1.8b": "internlm2_1_8b",
    "glm4-9b": "glm4_9b",
    "smollm-360m": "smollm_360m",
    "zamba2-1.2b": "zamba2_1_2b",
    "internvl2-1b": "internvl2_1b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-130m": "mamba2_130m",
    "whisper-medium": "whisper_medium",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE
