"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, d_ff=0,
    vocab_size=50_280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    vocab_size=512, ssm_state=16, ssm_head_dim=16, remat=False,
)
