"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32_000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    sliding_window=4096,   # shared block runs SWA (long-context safe)
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, ssm_state=16, ssm_head_dim=16, attn_every=2,
    sliding_window=16, remat=False,
)
