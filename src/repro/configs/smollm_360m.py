"""smollm-360m [dense] — llama-arch small, GQA kv=5.
[hf:HuggingFaceTB/SmolLM-360M; hf]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49_152,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab_size=512, remat=False,
)
