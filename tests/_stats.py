"""CLT-derived assertion helpers for stochastic estimators.

The GRF harness's bounds are *derived*, never hand-tuned: every tolerance
comes from the estimator's own measured spread and a fixed z-score, so a
test can only pass because the estimator is actually unbiased at the
stated confidence — not because someone widened an atol until CI went
green.  With fixed seeds the draws are deterministic, so a passing bound
stays passing (no flaky tolerances); Z = 5 puts the per-element false-trip
probability under 6e-7, far below the element counts these tests check.
"""
from __future__ import annotations

import math

import numpy as np

# five standard errors: per-element false-positive probability < 5.8e-7,
# small against the O(1e4) elements a harness run checks, while a real
# bias of even one standard error trips it with near certainty as m grows
Z_SCORE = 5.0

# numeric floor added to every CLT bound: float32 accumulation error can
# dominate when the sampled spread is ~0 (e.g. deterministic columns),
# where a pure z * sem bound would demand exact bit equality
NUMERIC_FLOOR = 1e-5


def assert_unbiased(samples, oracle, *, axis: int = 1, z: float = Z_SCORE,
                    floor: float = NUMERIC_FLOOR, what: str = "estimate"):
    """Assert ``mean(samples, axis)`` is within ``z`` SEMs of ``oracle``.

    ``samples`` holds independent replicates along ``axis`` (walkers or
    seeds); the bound is elementwise ``|mean - oracle| <= z * sem + floor``
    with ``sem = std / sqrt(reps)`` estimated from the same samples (reps
    large enough that the Student-t correction is negligible).
    """
    samples = np.asarray(samples, np.float64)
    oracle = np.asarray(oracle, np.float64)
    reps = samples.shape[axis]
    assert reps >= 16, f"need >= 16 replicates for a stable SEM, got {reps}"
    mean = samples.mean(axis=axis)
    sem = samples.std(axis=axis, ddof=1) / math.sqrt(reps)
    err = np.abs(mean - oracle)
    bound = z * sem + floor
    worst = np.max(err - bound)
    assert (err <= bound).all(), (
        f"{what} biased beyond {z} SEMs: worst excess {worst:.3e} "
        f"(max |err| {err.max():.3e}, max sem {sem.max():.3e}, "
        f"reps {reps})")


def variance_ratio_floor(m_small: int, m_big: int, reps: int,
                         z: float = Z_SCORE) -> float:
    """Smallest MSE ratio ``mse(m_small) / mse(m_big)`` the CLT guarantees.

    An unbiased MC mean over ``m`` draws has MSE proportional to ``1/m``,
    so the true ratio is ``m_big / m_small``.  Each MSE is *estimated*
    from ``reps`` independent replicates, and a mean of ``reps`` squared
    errors concentrates within a relative ``z * sqrt(2 / reps)`` of its
    expectation (chi-square CLT; conservative — it ignores the additional
    averaging over elements).  Dividing the true ratio by the two-sided
    slack gives a floor that only genuine variance non-decay can breach.
    """
    slack = 1.0 + z * math.sqrt(2.0 / reps)
    return (m_big / m_small) / (slack * slack)


def assert_variance_decays(sq_errs_small, sq_errs_big, *, m_small: int,
                           m_big: int, z: float = Z_SCORE):
    """Assert the MSE shrinks like 1/m between two walker budgets.

    ``sq_errs_*`` are per-replicate mean squared errors against the exact
    oracle (one scalar per seed).  The ratio must clear
    :func:`variance_ratio_floor` — derived from the replicate count, not
    tuned.
    """
    sq_errs_small = np.asarray(sq_errs_small, np.float64)
    sq_errs_big = np.asarray(sq_errs_big, np.float64)
    reps = min(sq_errs_small.size, sq_errs_big.size)
    mse_small = sq_errs_small.mean()
    mse_big = sq_errs_big.mean()
    floor = variance_ratio_floor(m_small, m_big, reps, z=z)
    assert floor > 1.0, (
        f"test design error: floor {floor:.2f} <= 1 cannot distinguish "
        f"decay from noise; raise m_big/m_small or reps")
    ratio = mse_small / mse_big
    assert ratio >= floor, (
        f"variance did not decay with walkers: mse({m_small}w) / "
        f"mse({m_big}w) = {ratio:.2f} < CLT floor {floor:.2f} "
        f"(true ratio would be {m_big / m_small:.1f})")
