"""Distribution correctness on multi-device CPU meshes (subprocess-isolated
because XLA fixes the host device count per process).

Covers: sharded-vs-single-device train-step parity, the distributed VDT LP
step vs the reference matvec, and the pod-axis pipeline schedule.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(body: str, n_dev: int = 8, timeout: int = 420) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import sys
        sys.path.insert(0, {SRC!r})
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """One train step on a 4x2 mesh must match the unsharded step."""
    _run("""
        from repro.configs.registry import get_smoke_config
        from repro.distributed.sharding import ShardCtx, param_shardings, use_ctx
        from repro.models.transformer import init_lm
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_step import init_train_state, make_train_step

        cfg = get_smoke_config("internlm2-1.8b")
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        params = init_lm(cfg, jax.random.PRNGKey(0))
        state = init_train_state(params, opt)
        r = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
            r.randint(0, cfg.vocab_size, (8, 33)), jnp.int32)}
        step = make_train_step(cfg, opt)

        # single-logical-device reference
        s1, m1 = jax.jit(step)(state, batch)

        # sharded: FSDP over data(4) x TP over model(2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = ShardCtx(mesh=mesh, dp=("data",))
        ps = param_shardings(params, ctx)
        st_sh = type(state)(params=ps,
                            opt=type(state.opt)(step=NamedSharding(mesh, P()),
                                                mu=ps, nu=ps),
                            step=NamedSharding(mesh, P()))
        bt_sh = {"tokens": NamedSharding(mesh, P("data", None))}

        def fn(s, b):
            with use_ctx(ctx):
                return step(s, b)

        with mesh:
            s2, m2 = jax.jit(fn, in_shardings=(st_sh, bt_sh))(state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, (
            float(m1["loss"]), float(m2["loss"]))
        # parameters after update agree
        l1 = jax.tree_util.tree_leaves(s1.params)
        l2 = jax.tree_util.tree_leaves(s2.params)
        worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
                    for a, b in zip(l1, l2))
        assert worst < 5e-2, worst
        print("PARITY OK", float(m1["loss"]), worst)
    """)


def test_distributed_vdt_lp_step_matches_reference():
    """The sharded paper_vdt LP step == the single-device block matvec."""
    _run("""
        from repro.core.distributed import lp_step_leaforder
        from repro.core.tree import build_tree
        from repro.core.blocks import coarsest_partition
        from repro.core.qopt import optimize_q
        from repro.core.matvec import mpt_matvec_leaforder

        r = np.random.RandomState(0)
        n, d, c = 1024, 8, 4
        x = r.randn(n, d).astype(np.float32)
        tree = build_tree(x)
        bp = coarsest_partition(tree)
        qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                        jnp.asarray(bp.active), jnp.asarray(1.0))
        q = jnp.where(jnp.isfinite(qs.log_q), jnp.exp(qs.log_q), 0.0)
        y = jnp.asarray(r.randn(n, c), jnp.float32)
        y0 = jnp.asarray(r.randn(n, c), jnp.float32)
        alpha = 0.3

        ref = alpha * mpt_matvec_leaforder(y, jnp.asarray(bp.a),
                                           jnp.asarray(bp.b), q, tree.L) \\
              + (1 - alpha) * y0

        # pad blocks to a shard-divisible count with inert q=0 entries
        nb = bp.a.shape[0]
        pad = (-nb) % 8
        a = jnp.pad(jnp.asarray(bp.a), (0, pad))
        b = jnp.pad(jnp.asarray(bp.b), (0, pad))
        qq = jnp.pad(q, (0, pad))

        mesh = jax.make_mesh((8,), ("data",))
        sh_rows = NamedSharding(mesh, P("data", None))
        sh_blocks = NamedSharding(mesh, P("data"))
        with mesh:
            got = jax.jit(
                lambda yl, y0l, aa, bb, qv: lp_step_leaforder(
                    yl, y0l, aa, bb, qv, alpha, tree.L),
                in_shardings=(sh_rows, sh_rows, sh_blocks, sh_blocks,
                              sh_blocks),
            )(y, y0, a, b, qq)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        print("VDT DIST OK")
    """)


def test_pipeline_matches_sequential():
    """GPipe over a 4-stage pod axis == running stages sequentially."""
    _run("""
        from repro.distributed.pipeline import pipeline_forward

        n_stages, n_micro, mb, dim = 4, 8, 2, 16
        r = np.random.RandomState(0)
        ws = jnp.asarray(r.randn(n_stages, dim, dim) * 0.3, jnp.float32)
        x = jnp.asarray(r.randn(n_micro, mb, dim), jnp.float32)

        def stage_fn(w, h, stage_idx):
            return jnp.tanh(h @ w)

        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ ws[s])

        mesh = jax.make_mesh((4,), ("pod",))
        with mesh:
            got = pipeline_forward(stage_fn, ws, x, mesh, axis="pod")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        print("PIPELINE OK")
    """, n_dev=4)
