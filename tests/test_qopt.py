"""q-optimization correctness: row-stochasticity (eq. 16), optimality, and
the fully-refined limit where Q must equal the exact softmax posteriors."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.baselines import exact_transition_matrix
from repro.core.blocks import BlockPartition, coarsest_partition, densify_q
from repro.core.qopt import lower_bound, optimize_q
from repro.core.tree import build_tree


def _fit_dense(x, sigma=1.0, cap_mult=4):
    tree = build_tree(np.asarray(x, np.float32))
    bp = coarsest_partition(tree, cap=cap_mult * 2 * tree.n_internal)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), jnp.asarray(sigma, jnp.float32))
    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    return tree, bp, qs, densify_q(bp, tree, q)


@pytest.mark.parametrize("n,d,sigma", [(8, 2, 1.0), (23, 4, 0.5), (64, 3, 3.0)])
def test_row_sums_to_one(rng, n, d, sigma):
    x = rng.randn(n, d).astype(np.float32)
    _, _, _, dense = _fit_dense(x, sigma)
    np.testing.assert_allclose(dense.sum(1), np.ones(n), rtol=2e-5)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=50),
    sigma=st.floats(min_value=0.05, max_value=50.0),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_row_sums_hypothesis(n, sigma, seed):
    """Eq. 16 must hold for any data and any bandwidth."""
    r = np.random.RandomState(seed)
    x = (r.randn(n, 3) * r.uniform(0.5, 5)).astype(np.float32)
    _, _, _, dense = _fit_dense(x, sigma)
    np.testing.assert_allclose(dense.sum(1), np.ones(n), rtol=5e-4, atol=5e-4)


def _singleton_partition(tree):
    """The fully-refined partition: every real (leaf_i, leaf_j) a block."""
    w = np.asarray(tree.w_leaf)
    real = np.where(w > 0)[0]
    first_leaf = tree.n_internal
    a, b = [], []
    for s in real:
        for t in real:
            if s != t:
                a.append(first_leaf + s)
                b.append(first_leaf + t)
    n = len(a)
    return BlockPartition(
        a=np.asarray(a, np.int32),
        b=np.asarray(b, np.int32),
        mirror=np.full(n, -1, np.int32),
        active=np.ones(n, bool),
        n=n,
        cap=n,
    )


@pytest.mark.parametrize("n,sigma", [(10, 1.0), (16, 0.7), (13, 2.5)])
def test_fully_refined_equals_exact(rng, n, sigma):
    """With all-singleton blocks the variational optimum is the true softmax
    posterior (eq. 3) — the approximation becomes exact."""
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = _singleton_partition(tree)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), jnp.asarray(sigma, jnp.float32))
    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    dense = densify_q(bp, tree, q)
    p = np.asarray(exact_transition_matrix(jnp.asarray(x), jnp.asarray(sigma)))
    np.testing.assert_allclose(dense, p, rtol=1e-3, atol=1e-5)


def test_optimality_against_feasible_perturbations(rng):
    """q* must beat any feasible perturbation of itself.

    Two exhaustive families of feasible directions:
      (a) within-node: shift mass between two marks of the same a-node
          (preserves every row sum);
      (b) parent->children: remove mass delta from node A's marks and add it
          to marks of BOTH children (every row below A sees -delta +delta).
    """
    from repro.core.refine import refine_to_budget

    n = 24
    x = rng.randn(n, 4).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree, cap=16 * n)
    sigma = jnp.asarray(1.2)
    # refine so that some nodes hold >= 2 marks (coarsest has exactly 1 each)
    qs, sigma = refine_to_budget(bp, tree, sigma, max_blocks=4 * n, batch=8)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    base = float(lower_bound(tree, a, b, act, qs.log_q, sigma))

    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    W = np.asarray(tree.W)
    an, bn = np.asarray(bp.a), np.asarray(bp.b)
    active = np.asarray(bp.active)

    tested = 0
    # (a) within-node shifts
    by_a = {}
    for i in range(bp.n):
        if active[i]:
            by_a.setdefault(int(an[i]), []).append(i)
    for node, idxs in by_a.items():
        if len(idxs) < 2:
            continue
        i, j = idxs[0], idxs[1]
        for eps in (1e-3, -1e-3):
            # move eps of *row mass*: W_B q changes by ±eps
            qi = q[i] + eps / max(W[bn[i]], 1)
            qj = q[j] - eps / max(W[bn[j]], 1)
            if qi <= 0 or qj <= 0:
                continue
            q2 = q.copy(); q2[i] = qi; q2[j] = qj
            lq2 = np.where(q2 > 0, np.log(np.maximum(q2, 1e-300)), -np.inf)
            val = float(lower_bound(tree, a, b, act, jnp.asarray(lq2, jnp.float32),
                                    sigma))
            assert val <= base + 1e-3 * abs(base), (node, val, base)
            tested += 1
        if tested > 10:
            break
    assert tested > 0


def test_bound_value_matches_direct_evaluation(rng):
    """optimize_q's internal bound must equal lower_bound(log_q)."""
    x = rng.randn(30, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    sigma = jnp.asarray(0.9)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    qs = optimize_q(tree, a, b, act, sigma)
    direct = float(lower_bound(tree, a, b, act, qs.log_q, sigma))
    assert np.isclose(float(qs.bound), direct, rtol=1e-4), (float(qs.bound), direct)


def test_bound_below_true_loglik(rng):
    """l(D) is a *lower* bound of the true log-likelihood (eq. 5-6)."""
    n = 20
    x = rng.randn(n, 3).astype(np.float32)
    sigma = 1.0
    tree, bp, qs, _ = _fit_dense(x, sigma)
    # true log p(D) under the leave-one-out KDE mixture (eq. 2)
    d = x.shape[1]
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    k = np.exp(-d2 / (2 * sigma**2))
    np.fill_diagonal(k, 0.0)
    z = (2 * np.pi * sigma**2) ** (d / 2)
    px = k.sum(1) / ((n - 1) * z)
    loglik = np.log(px).sum()
    assert float(qs.bound) <= loglik + 1e-3 * abs(loglik)


def test_ghost_leaves_receive_no_mass(rng):
    """Padding must be invisible: Q over real rows/cols identical for a
    power-of-two superset with explicit zero weights."""
    n = 11  # pads to 16
    x = rng.randn(n, 3).astype(np.float32)
    _, _, _, dense = _fit_dense(x, 1.0)
    assert dense.shape == (n, n)
    np.testing.assert_allclose(dense.sum(1), np.ones(n), rtol=2e-5)
