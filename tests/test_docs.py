"""Documentation contract: README/docs code blocks compile and run, links
resolve — the same checks CI's docs job runs via tools/check_docs.py, so a
broken quickstart or dead link fails tier-1 locally first."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
import check_docs  # noqa: E402


def test_doc_files_exist():
    files = [p.name for p in check_docs.doc_files()]
    assert "README.md" in files
    assert "ARCHITECTURE.md" in files


@pytest.mark.parametrize("path", check_docs.doc_files(),
                         ids=lambda p: p.name)
def test_python_blocks_compile(path):
    assert check_docs.check_code_blocks(path, run=False) == []


@pytest.mark.parametrize("path", check_docs.doc_files(),
                         ids=lambda p: p.name)
def test_intra_repo_links_resolve(path):
    assert check_docs.check_links(path) == []


def test_readme_quickstart_runs():
    """The README quickstart executes as-is (PYTHONPATH=src, subprocess) —
    the PR's acceptance criterion for a clean checkout."""
    readme = check_docs.REPO_ROOT / "README.md"
    failures = check_docs.check_code_blocks(readme, run=True, timeout=240.0)
    assert failures == []


def test_extract_blocks_markers():
    text = "\n".join([
        "prose",
        "<!-- docs-check: skip -->",
        "```python",
        "this is not : valid python",
        "```",
        "more prose resets the marker",
        "```python",
        "x = 1",
        "```",
    ])
    blocks = check_docs.extract_blocks(text)
    assert [(lang, tag) for _, lang, tag, _ in blocks] == [
        ("python", "skip"), ("python", "")]
