"""GRF backend: CLT-bounded unbiasedness, variance decay, invariants,
differentials vs the exact backend, routing boundaries, and engine serving.

Every stochastic assertion goes through ``tests/_stats.py``: bounds are
derived from the estimator's own sampled spread at Z = 5 — never a
hand-tuned atol — and all seeds are fixed, so each test is deterministic
(a pass today is a pass tomorrow; see the _stats module docstring).
"""
import math

import numpy as np
import pytest

from repro.core.grf import (CSRGraph, MAX_RTOL_WALKERS, grf_label_propagate,
                            grf_transition_action, sample_walks,
                            walkers_for_rtol)
from repro.core.label_prop import (AUTO_EXACT_MAX_N, AUTO_GRF_MAX_DENSITY,
                                   AUTO_GRF_MIN_RTOL, route_backend)
from repro.kernels.grf.ref import dense_lp_ref, dense_power_action_ref
from tests._stats import assert_unbiased, assert_variance_decays

N = 24          # graph size for the statistical harness — small enough
#                 that m = 2048 walkers per node stays cheap on CPU
DEG = 4         # out-degree of the random test graph (density 4/24 ~ 0.17)


def _random_graph(rng, n=N, deg=DEG):
    """Connected-ish random sparse digraph with non-uniform edge weights.

    Non-uniform weights matter: they exercise the importance correction
    ``deg(u) * P[u, v]`` (uniform weights make it degenerate to 1 on
    regular graphs, which would hide a broken multiplier).
    """
    indptr = np.arange(n + 1, dtype=np.int64) * deg
    indices = np.concatenate(
        [rng.choice(n, size=deg, replace=False) for _ in range(n)])
    weights = rng.rand(n * deg) + 0.1
    return CSRGraph.from_csr(indptr, indices, weights)


@pytest.fixture(scope="module")
def graph():
    return _random_graph(np.random.RandomState(11))


@pytest.fixture(scope="module")
def dense_p(graph):
    return graph.dense_p()


# -------------------------------------------------------- unbiasedness
@pytest.mark.parametrize("t", [0, 1, 3, 7])
def test_transition_action_unbiased(graph, dense_p, t):
    """Walker-mean of P^t y is within 5 SEMs of the dense oracle, per
    element, with the SEM measured from the walkers themselves."""
    rng = np.random.RandomState(100 + t)
    y = rng.randn(N).astype(np.float32)
    oracle = dense_power_action_ref(dense_p, y, t)
    est, samples = grf_transition_action(
        graph, y, t=t, n_walkers=2048, seed=t, return_samples=True,
        impl="ref")
    assert_unbiased(np.asarray(samples), np.asarray(oracle), axis=1,
                    what=f"P^{t} y walker mean")
    np.testing.assert_allclose(np.asarray(est),
                               np.asarray(samples).mean(axis=1),
                               rtol=1e-5, atol=1e-6)


def test_transition_action_unbiased_with_halting(graph, dense_p):
    """Terminating walks (p_halt > 0) stay unbiased: the 1/(1 - p_halt)
    survivor correction exactly cancels the kill probability."""
    rng = np.random.RandomState(7)
    y = rng.randn(N).astype(np.float32)
    t = 3
    oracle = dense_power_action_ref(dense_p, y, t)
    _, samples = grf_transition_action(
        graph, y, t=t, n_walkers=4096, seed=5, p_halt=0.15,
        return_samples=True, impl="ref")
    assert_unbiased(np.asarray(samples), np.asarray(oracle), axis=1,
                    what="terminating-walk mean")


def test_variance_decays_with_walkers(graph, dense_p):
    """MSE shrinks like 1/m: the 8x walker budget must cut the replicate
    MSE by at least the chi-square CLT floor (derived, not tuned)."""
    rng = np.random.RandomState(21)
    y = rng.randn(N).astype(np.float32)
    t, reps, m_small, m_big = 3, 24, 8, 64
    oracle = np.asarray(dense_power_action_ref(dense_p, y, t), np.float64)

    def mses(m):
        out = []
        for seed in range(reps):
            est = grf_transition_action(graph, y, t=t, n_walkers=m,
                                        seed=1000 + seed, impl="ref")
            out.append(np.mean((np.asarray(est, np.float64) - oracle) ** 2))
        return out

    assert_variance_decays(mses(m_small), mses(m_big),
                           m_small=m_small, m_big=m_big)


# ---------------------------------------------------------- invariants
def test_row_stochastic_and_nonnegative(graph):
    """P^t 1 = 1 (within CLT bounds) and the action preserves sign: a
    non-negative label vector can never produce a negative estimate
    (loads are products of non-negative multipliers)."""
    ones = np.ones(N, np.float32)
    _, samples = grf_transition_action(graph, ones, t=5, n_walkers=2048,
                                       seed=3, return_samples=True,
                                       impl="ref")
    assert_unbiased(np.asarray(samples), ones, axis=1,
                    what="row-sum estimate")
    assert (np.asarray(samples) >= 0.0).all()

    y = np.abs(np.random.RandomState(4).randn(N, 3)).astype(np.float32)
    est = grf_transition_action(graph, y, t=4, n_walkers=64, seed=9,
                                impl="ref")
    assert (np.asarray(est) >= 0.0).all()


def test_walk_loads_nonnegative_and_t0_exact(graph):
    pos, load = sample_walks(graph, n_steps=4, n_walkers=16, seed=0)
    pos, load = np.asarray(pos), np.asarray(load)
    assert (load >= 0.0).all()
    # t=0 column: every walker sits at its start node with load exactly 1
    assert (pos[:, :, 0] == np.arange(N)[:, None]).all()
    assert (load[:, :, 0] == 1.0).all()


# --------------------------------------------- determinism / prefix pins
def test_walks_deterministic_and_prefix(graph):
    """Same seed -> bit-identical walks; a horizon-T walk set is a prefix
    of the horizon-T' one (step t's randomness is fold_in(key, t))."""
    p1, l1 = sample_walks(graph, n_steps=3, n_walkers=8, seed=42)
    p2, l2 = sample_walks(graph, n_steps=3, n_walkers=8, seed=42)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    p7, l7 = sample_walks(graph, n_steps=7, n_walkers=8, seed=42)
    assert np.array_equal(np.asarray(p1), np.asarray(p7)[:, :, :4])
    assert np.array_equal(np.asarray(l1), np.asarray(l7)[:, :, :4])
    p_other, _ = sample_walks(graph, n_steps=3, n_walkers=8, seed=43)
    assert not np.array_equal(np.asarray(p1), np.asarray(p_other))


def test_label_propagate_deterministic_and_fold_parity(graph):
    """Repeated LP dispatches are bit-identical per seed, and a batched
    (folded) dispatch reproduces each member's solo dispatch bit-for-bit
    — the property the serving tier's coalescing leans on (walker paths
    are label-independent, so the folded stack shares one walk set)."""
    rng = np.random.RandomState(6)
    y0a = rng.rand(N, 2).astype(np.float32)
    y0b = rng.rand(N, 2).astype(np.float32)
    kw = dict(n_iters=6, n_walkers=16, seed=12, impl="ref")
    solo_a = np.asarray(grf_label_propagate(graph, y0a, alpha=0.05, **kw))
    again = np.asarray(grf_label_propagate(graph, y0a, alpha=0.05, **kw))
    assert np.array_equal(solo_a, again)
    solo_b = np.asarray(grf_label_propagate(graph, y0b, alpha=0.2, **kw))
    batched = np.asarray(grf_label_propagate(
        graph, np.stack([y0a, y0b]), alpha=np.array([0.05, 0.2]), **kw))
    assert np.array_equal(batched[0], solo_a)
    assert np.array_equal(batched[1], solo_b)


def test_feature_kernel_matches_ref(graph):
    """The Pallas one-hot-matmul feature reduction equals the jnp oracle."""
    rng = np.random.RandomState(13)
    y = rng.randn(N, 3).astype(np.float32)
    t = 4
    a = grf_transition_action(graph, y, t=t, n_walkers=32, seed=2)
    b = grf_transition_action(graph, y, t=t, n_walkers=32, seed=2,
                              impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError, match="impl"):
        grf_transition_action(graph, y, t=1, n_walkers=4, impl="fast")


# -------------------------------------------------- differential: LP
def test_lp_unbiased_vs_dense_reference(graph, dense_p):
    """grf_label_propagate across seeds is centred on the dense eq.-15
    fixed reference (seed-replicate CLT bound)."""
    rng = np.random.RandomState(17)
    y0 = rng.rand(N, 2).astype(np.float32)
    alpha, n_iters, reps = 0.1, 12, 16
    oracle = np.asarray(dense_lp_ref(dense_p, y0, alpha=alpha,
                                     n_iters=n_iters))
    ests = np.stack([
        np.asarray(grf_label_propagate(graph, y0, alpha=alpha,
                                       n_iters=n_iters, n_walkers=256,
                                       seed=s, impl="ref"))
        for s in range(reps)])
    assert_unbiased(ests, oracle, axis=0, what="grf LP vs dense_lp_ref")


def test_lp_alpha_zero_and_zero_iters(graph):
    """Degenerate recipes are exact, not just unbiased: alpha=0 returns
    the seed labels untouched, and so does n_iters=0 (the t=0 term)."""
    y0 = np.random.RandomState(8).rand(N, 2).astype(np.float32)
    out0 = grf_label_propagate(graph, y0, alpha=0.0, n_iters=5,
                               n_walkers=4, seed=0, impl="ref")
    np.testing.assert_allclose(np.asarray(out0), y0, rtol=1e-6, atol=1e-6)
    outz = grf_label_propagate(graph, y0, alpha=0.3, n_iters=0,
                               n_walkers=4, seed=0, impl="ref")
    np.testing.assert_allclose(np.asarray(outz), y0, rtol=1e-6, atol=1e-6)


def test_grf_backend_unbiased_vs_exact_backend(small_fitted_vdt):
    """Model-level differential: VariationalDualTree.label_propagate
    (backend='grf') across seeds is centred on backend='exact' — both
    walk the SAME eq.-3 matrix (from_points bridges it), so any bias is
    a real estimator bug, not a model difference."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(23)
    y0 = (rng.rand(x.shape[0], 2) > 0.7).astype(np.float32)
    alpha, n_iters, reps = 0.1, 6, 16
    want = np.asarray(vdt.label_propagate(y0, alpha=alpha, n_iters=n_iters,
                                          backend="exact"))
    ests = np.stack([
        np.asarray(vdt.label_propagate(y0, alpha=alpha, n_iters=n_iters,
                                       backend="grf", n_walkers=128,
                                       seed=s))
        for s in range(reps)])
    assert_unbiased(ests, want, axis=0, what="grf backend vs exact backend")


def test_grf_graph_matches_exact_matrix(small_fitted_vdt):
    """The bridged CSR graph scatters back to exactly the dense eq.-3
    row-softmax the exact backend streams."""
    from repro.kernels.fused_lp.ref import dense_transition_ref

    x, vdt = small_fitted_vdt
    want = np.asarray(dense_transition_ref(x, float(vdt.sigma)))
    got = vdt.grf_graph().dense_p()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert vdt.grf_graph() is vdt.grf_graph()  # cached per instance


def test_grf_backend_rejects_resume(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 2), np.float32)
    with pytest.raises(ValueError, match="resume"):
        vdt.label_propagate_resume(y0, y0, n_iters=2, backend="grf")


# ----------------------------------------------------- divergence gating
def test_positive_domain_divergences_rejected():
    x = (np.random.RandomState(5).rand(12, 3) + 0.5).astype(np.float32)
    for div in ("kl", "itakura_saito"):
        with pytest.raises(ValueError, match="grf"):
            CSRGraph.from_points(x, 1.0, divergence=div)
    CSRGraph.from_points(x, 1.0)  # euclidean path is fine


def test_kl_fitted_model_rejects_grf_backend():
    from repro.core.vdt import VariationalDualTree

    x = (np.random.RandomState(6).rand(12, 3) + 0.5).astype(np.float32)
    vdt = VariationalDualTree.fit(x, sigma=1.0, learn_sigma=False,
                                  divergence="kl", max_blocks=4 * 12)
    y0 = np.zeros((12, 1), np.float32)
    with pytest.raises(ValueError, match="grf"):
        vdt.label_propagate(y0, n_iters=2, backend="grf")


# ------------------------------------------------------ CSR construction
def test_csr_roundtrip_and_row_stochastic(graph, dense_p):
    assert dense_p.shape == (N, N)
    np.testing.assert_allclose(dense_p.sum(axis=1), 1.0, rtol=1e-5)
    assert (dense_p >= 0).all()
    assert graph.nnz == N * DEG
    assert graph.density == pytest.approx(DEG / N)
    back = CSRGraph.from_dense(dense_p)
    np.testing.assert_allclose(back.dense_p(), dense_p, rtol=1e-5,
                               atol=1e-7)


def test_csr_validation_errors():
    with pytest.raises(ValueError, match="monotone"):
        CSRGraph.from_csr([0, 2, 1], [0, 1])
    with pytest.raises(ValueError, match="outgoing edge"):
        CSRGraph.from_csr([0, 1, 1], [0])
    with pytest.raises(ValueError, match="indices"):
        CSRGraph.from_csr([0, 1, 2], [0, 5])
    with pytest.raises(ValueError, match="weights shape"):
        CSRGraph.from_csr([0, 1, 2], [0, 1], weights=[1.0])
    with pytest.raises(ValueError, match="finite"):
        CSRGraph.from_csr([0, 1, 2], [0, 1], weights=[1.0, -1.0])
    with pytest.raises(ValueError, match="zero total weight"):
        CSRGraph.from_csr([0, 1, 2], [0, 1], weights=[1.0, 0.0])
    with pytest.raises(ValueError, match="square"):
        CSRGraph.from_dense(np.zeros((2, 3)))
    with pytest.raises(ValueError, match="indptr"):
        CSRGraph.from_csr([0], [])


# ------------------------------------------------------------- routing
def test_walkers_for_rtol_clt_sizing():
    assert walkers_for_rtol(0.1) == 100
    assert walkers_for_rtol(0.05) == 400
    assert walkers_for_rtol(1.0) == 1
    assert walkers_for_rtol(1e-9) == MAX_RTOL_WALKERS  # capped
    assert walkers_for_rtol(0.07) == math.ceil(1 / 0.07 ** 2)
    with pytest.raises(ValueError):
        walkers_for_rtol(0.0)
    with pytest.raises(ValueError):
        walkers_for_rtol(-0.1)


def test_route_backend_exact_cutoff_boundary():
    """The auto exact/vdt cutoff is the named constant, inclusive at
    exactly AUTO_EXACT_MAX_N, and overridable per call."""
    assert AUTO_EXACT_MAX_N == 1024
    assert route_backend("auto", n=AUTO_EXACT_MAX_N) == "exact"
    assert route_backend("auto", n=AUTO_EXACT_MAX_N + 1) == "vdt"
    assert route_backend("auto", n=2000, auto_exact_max_n=4096) == "exact"
    assert route_backend("auto", n=8, auto_exact_max_n=4) == "vdt"


def test_route_backend_grf_grid():
    """auto -> grf iff BOTH density and rtol are stated and permissive
    (boundaries inclusive); missing either hint disqualifies grf."""
    d, r = AUTO_GRF_MAX_DENSITY, AUTO_GRF_MIN_RTOL
    assert route_backend("auto", density=d, rtol=r) == "grf"
    assert route_backend("auto", density=d / 2, rtol=0.5) == "grf"
    # one hint off the boundary -> falls through to the size rule
    assert route_backend("auto", n=10, density=d * 1.01, rtol=r) == "exact"
    assert route_backend("auto", n=10, density=d, rtol=r * 0.99) == "exact"
    # an unstated hint never routes grf
    assert route_backend("auto", n=10, rtol=0.5) == "exact"
    assert route_backend("auto", n=2000, density=0.01) == "vdt"


def test_route_backend_passthrough_and_errors():
    assert route_backend(None, "vdt") == "vdt"
    assert route_backend(None, "grf") == "grf"
    assert route_backend("grf") == "grf"
    # explicit tags ignore the hints entirely
    assert route_backend("exact", n=10 ** 9) == "exact"
    assert route_backend("vdt", density=0.001, rtol=0.5) == "vdt"
    with pytest.raises(ValueError, match="needs the problem size"):
        route_backend("auto")
    with pytest.raises(ValueError, match="backend must be one of"):
        route_backend("dense")


# ------------------------------------------------------------- serving
def test_engine_grf_coalesces_at_max_budget(small_fitted_vdt):
    """Heterogeneous walker budgets share ONE dispatch at the max budget
    (n_walkers is deliberately not in the group key); the gauge reports
    the budget device work actually ran at."""
    from repro.serving import PropagateEngine, PropagateRequest

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    rng = np.random.RandomState(2)

    def mk():
        return (rng.rand(n, 2) > 0.8).astype(np.float32)

    eng = PropagateEngine(vdt, start=False, max_batch=8, backend="grf",
                          n_walkers=8)
    futs = [
        eng.submit(PropagateRequest(mk(), n_iters=4, n_walkers=32)),
        eng.submit(PropagateRequest(mk(), n_iters=4, rtol=0.25)),  # -> 16
        eng.submit(PropagateRequest(mk(), n_iters=4)),  # engine default 8
        eng.submit(PropagateRequest(mk(), alpha=0.2, n_iters=4)),
    ]
    eng.flush()
    for f in futs:
        assert f.result(timeout=0).shape == (n, 2)
    m = eng.metrics()
    assert m.dispatches == 1 and m.batched_requests == 4
    assert m.n_walkers == 32
    eng.shutdown()


def test_engine_grf_bit_identical_per_seed(small_fitted_vdt):
    """Two engines sharing grf_seed resolve the same requests to the same
    bits; a different grf_seed resolves differently."""
    from repro.serving import PropagateEngine, PropagateRequest

    x, vdt = small_fitted_vdt
    n = x.shape[0]

    def run(grf_seed):
        rng = np.random.RandomState(14)
        reqs = [PropagateRequest((rng.rand(n, 2) > 0.8).astype(np.float32),
                                 alpha=a, n_iters=4)
                for a in (0.01, 0.2, 0.05)]
        eng = PropagateEngine(vdt, start=False, max_batch=4, backend="grf",
                              n_walkers=8, grf_seed=grf_seed)
        futs = [eng.submit(q) for q in reqs]
        eng.flush()
        out = [np.asarray(f.result(timeout=0)) for f in futs]
        eng.shutdown()
        return out

    a, b, c = run(0), run(0), run(1)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra, rb)
    assert any(not np.array_equal(ra, rc) for ra, rc in zip(a, c))


def test_engine_grf_mixed_backends_split_dispatch(small_fitted_vdt):
    """grf and vdt requests never share a dispatch (backend is in the
    group key), and each answer matches its single-model call."""
    from repro.serving import PropagateEngine, PropagateRequest

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    rng = np.random.RandomState(15)
    y_grf = (rng.rand(n, 2) > 0.8).astype(np.float32)
    y_vdt = (rng.rand(n, 2) > 0.8).astype(np.float32)
    eng = PropagateEngine(vdt, start=False, max_batch=8, n_walkers=8)
    f_grf = eng.submit(PropagateRequest(y_grf, n_iters=4, backend="grf"))
    f_vdt = eng.submit(PropagateRequest(y_vdt, n_iters=4, backend="vdt"))
    eng.flush()
    assert eng.metrics().dispatches == 2
    want_vdt = vdt.label_propagate(y_vdt, alpha=0.01, n_iters=4)
    np.testing.assert_allclose(np.asarray(f_vdt.result(timeout=0)),
                               np.asarray(want_vdt), rtol=1e-5, atol=1e-6)
    assert f_grf.result(timeout=0).shape == (n, 2)
    eng.shutdown()


def test_engine_grf_warmup_and_validation_pins(small_fitted_vdt):
    from repro.serving import PropagateEngine, PropagateRequest

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    eng = PropagateEngine(vdt, start=False, backend="grf", n_walkers=4)
    assert eng.warmup(widths=(2,), n_iters=(4,), backends=("grf",)) > 0
    y0 = np.zeros((n, 2), np.float32)
    for bad in (dict(rtol=0.0), dict(rtol=2.0), dict(rtol=float("nan")),
                dict(n_walkers=0), dict(n_walkers=-3)):
        with pytest.raises(ValueError):
            eng.submit(PropagateRequest(y0, n_iters=2, **bad))
    with pytest.raises(ValueError):
        PropagateEngine(vdt, start=False, backend="grf", n_walkers=0)
    eng.shutdown()


def test_engine_auto_never_routes_grf(small_fitted_vdt):
    """An engine serves the complete kernel graph (density ~1), so auto
    traffic — even with a permissive rtol — resolves to exact/vdt."""
    from repro.serving import PropagateEngine, PropagateRequest
    from repro.serving._batching import DEFAULT_WIDTH_BUCKETS

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    req = PropagateRequest(np.zeros((n, 2), np.float32), n_iters=2,
                           backend="auto", rtol=0.5)
    resolved = req.validate(n=n, buckets=DEFAULT_WIDTH_BUCKETS)
    assert resolved.backend == "exact"  # n <= AUTO_EXACT_MAX_N size rule
    eng = PropagateEngine(vdt, start=False)
    fut = eng.submit(req)
    eng.flush()
    want = vdt.label_propagate(req.y0, alpha=req.alpha, n_iters=2,
                               backend="exact")
    np.testing.assert_allclose(np.asarray(fut.result(timeout=0)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)
    eng.shutdown()
