"""Refinement gains (eq. 18-19), bound monotonicity, and bandwidth learning
(eq. 12/14)."""
import numpy as np

import jax.numpy as jnp

from repro.core.blocks import coarsest_partition
from repro.core.qopt import lower_bound, optimize_q
from repro.core.refine import refine_to_budget, refine_topk, refinement_gains
from repro.core.sigma import fit_sigma_q, sigma_init, sigma_star
from repro.core.tree import build_tree


def _fit(rng, n=32, d=3, sigma=1.0, cap_mult=8):
    x = rng.randn(n, d).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree, cap=cap_mult * 2 * tree.n_internal)
    sig = jnp.asarray(sigma, jnp.float32)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), sig)
    return x, tree, bp, qs, sig


def test_gains_nonnegative(rng):
    """Refinement gains are >= 0 by Jensen (paper: the bound can never
    decrease under refinement)."""
    _, tree, bp, qs, sig = _fit(rng)
    g = np.asarray(refinement_gains(
        tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active),
        qs.log_q, sig))
    finite = g[np.isfinite(g)]
    assert len(finite) > 0
    assert np.all(finite >= -1e-6)


def test_gain_is_lower_bound_of_actual_gain(rng):
    """Delta_h (eq. 19) must lower-bound the actual bound improvement after
    the refinement + global re-optimization (paper §4.4)."""
    _, tree, bp, qs, sig = _fit(rng, n=24)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    before = float(qs.bound)
    g = np.asarray(refinement_gains(tree, a, b, act, qs.log_q, sig))
    i = int(np.nanargmax(np.where(np.isfinite(g), g, -np.inf)))
    predicted = float(g[i])
    refine_topk(bp, tree, g, k=1)
    qs2 = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                     jnp.asarray(bp.active), sig)
    actual = float(qs2.bound) - before
    assert actual >= predicted - 1e-3 - 1e-4 * abs(before), (actual, predicted)


def test_bound_monotone_under_refinement(rng):
    _, tree, bp, qs, sig = _fit(rng, n=40)
    bounds = [float(qs.bound)]
    for target in (1.5, 2.0, 3.0):
        qs2, _ = refine_to_budget(bp, tree, sig,
                                  max_blocks=int(target * 2 * 39), batch=8)
        bounds.append(float(qs2.bound))
    diffs = np.diff(bounds)
    assert np.all(diffs >= -1e-3), bounds


def test_refinement_saturates_at_nlogn(rng):
    """Horizontal+symmetric refinement cannot exceed ~N log2 N blocks (the
    paper stops at O(N log N)); budget beyond that saturates gracefully."""
    n = 16
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree, cap=4 * n * n)
    refine_to_budget(bp, tree, jnp.asarray(1.0), max_blocks=n * n * 2, batch=4)
    assert bp.n_active == n * int(np.log2(n))


def test_sigma_init_matches_bruteforce(rng):
    """Eq. 14 via O(Nd) moments == brute-force pairwise computation."""
    n, d = 50, 4
    x = rng.randn(n, d).astype(np.float32)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    brute = np.sqrt(d2.sum() / d) / n
    fast = float(sigma_init(x))
    assert np.isclose(fast, brute, rtol=1e-4)


def test_sigma_star_maximizes_bound(rng):
    """Eq. 12 should beat nearby bandwidths for fixed q (quasi-concavity)."""
    _, tree, bp, qs, sig = _fit(rng, n=30, sigma=2.0)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    s_star = sigma_star(tree, a, b, act, qs.log_q)
    val_star = float(lower_bound(tree, a, b, act, qs.log_q, s_star))
    for mult in (0.7, 0.9, 1.1, 1.4):
        val = float(lower_bound(tree, a, b, act, qs.log_q, s_star * mult))
        assert val <= val_star + 1e-4 * abs(val_star)


def test_alternating_optimization_monotone(rng):
    """Each alternation step (q-opt at new sigma) must not decrease l(D)."""
    n = 28
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    sig = sigma_init(x)
    qs = optimize_q(tree, a, b, act, sig)
    prev = float(qs.bound)
    for _ in range(5):
        sig = sigma_star(tree, a, b, act, qs.log_q)
        qs = optimize_q(tree, a, b, act, sig)
        cur = float(qs.bound)
        assert cur >= prev - 1e-3 * abs(prev)
        prev = cur


def test_fit_sigma_q_converges(rng):
    n = 40
    x = rng.randn(n, 5).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    sig, qs, iters = fit_sigma_q(
        tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active),
        sigma_init(x))
    assert iters < 20
    assert float(sig) > 0
    assert np.isfinite(float(qs.bound))


def test_sigma_insensitive_to_init(rng):
    """Paper §4.2: convergence not sensitive to the initial sigma."""
    n = 36
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    args = (tree, jnp.asarray(bp.a), jnp.asarray(bp.b), jnp.asarray(bp.active))
    s1, _, _ = fit_sigma_q(*args, 0.05, max_iters=50)
    s2, _, _ = fit_sigma_q(*args, 50.0, max_iters=50)
    assert np.isclose(float(s1), float(s2), rtol=0.02)
