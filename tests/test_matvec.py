"""Matvec (Algorithm 1) must agree exactly with the densified block matrix."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.blocks import coarsest_partition, densify_q
from repro.core.matvec import mpt_matvec
from repro.core.qopt import optimize_q
from repro.core.refine import refine_to_budget
from repro.core.tree import build_tree


def _setup(rng_or_seed, n, d, sigma=1.0, refine_mult=0):
    r = (np.random.RandomState(rng_or_seed)
         if isinstance(rng_or_seed, int) else rng_or_seed)
    x = r.randn(n, d).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree, cap=8 * max(n, 8) * 4)
    sig = jnp.asarray(sigma, jnp.float32)
    if refine_mult:
        qs, sig = refine_to_budget(bp, tree, sig, refine_mult * bp.n_active, batch=8)
    else:
        qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                        jnp.asarray(bp.active), sig)
    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    dense = densify_q(bp, tree, q)
    return x, tree, bp, qs, dense, r


@pytest.mark.parametrize("n,d,c", [(8, 2, 1), (23, 4, 3), (64, 3, 5), (33, 5, 2)])
def test_matvec_matches_dense(n, d, c):
    x, tree, bp, qs, dense, r = _setup(n * 7 + d, n, d)
    y = r.randn(n, c).astype(np.float32)
    out = mpt_matvec(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                     jnp.asarray(bp.active), qs.log_q, y)
    np.testing.assert_allclose(np.asarray(out), dense @ y, rtol=1e-4, atol=1e-5)


def test_matvec_matches_dense_after_refinement():
    x, tree, bp, qs, dense, r = _setup(3, 30, 4, refine_mult=3)
    y = r.randn(30, 2).astype(np.float32)
    out = mpt_matvec(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                     jnp.asarray(bp.active), qs.log_q, y)
    np.testing.assert_allclose(np.asarray(out), dense @ y, rtol=1e-4, atol=1e-5)


def test_matvec_1d_vector():
    x, tree, bp, qs, dense, r = _setup(11, 17, 3)
    y = r.randn(17).astype(np.float32)
    out = mpt_matvec(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                     jnp.asarray(bp.active), qs.log_q, y)
    assert out.shape == (17,)
    np.testing.assert_allclose(np.asarray(out), dense @ y, rtol=1e-4, atol=1e-5)


def test_matvec_preserves_constant_vector():
    """Q is row-stochastic => Q @ 1 = 1."""
    x, tree, bp, qs, dense, r = _setup(5, 40, 3)
    ones = np.ones((40, 1), np.float32)
    out = mpt_matvec(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                     jnp.asarray(bp.active), qs.log_q, ones)
    np.testing.assert_allclose(np.asarray(out), ones, rtol=2e-5)


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=40),
    c=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_matvec_linear_and_correct_hypothesis(n, c, seed):
    x, tree, bp, qs, dense, r = _setup(seed % 1000, n, 3)
    y1 = r.randn(n, c).astype(np.float32)
    y2 = r.randn(n, c).astype(np.float32)
    a = jnp.asarray(bp.a); b = jnp.asarray(bp.b); act = jnp.asarray(bp.active)
    o1 = np.asarray(mpt_matvec(tree, a, b, act, qs.log_q, y1))
    o2 = np.asarray(mpt_matvec(tree, a, b, act, qs.log_q, y2))
    o12 = np.asarray(mpt_matvec(tree, a, b, act, qs.log_q, y1 + 2.0 * y2))
    np.testing.assert_allclose(o12, o1 + 2.0 * o2, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(o1, dense @ y1, rtol=1e-3, atol=1e-4)
