"""Sharded multi-device engine: bit-parity with the single-device engine.

Two test populations:

* **Single-device (D=1 mesh)** — run in tier-1 on the plain CPU device.
  A 1-device mesh makes every collective a no-op but compiles the SAME
  shard_map program, blocked row layout, and jit cache as the real thing,
  so the full code path (both backends, segmented EDF preemption, publish,
  capability surface, fleet integration, error paths) is exercised on
  every CI run.
* **Multi-device grid** — requires >= 2 devices; the CI ``sharded`` leg
  provides 8 via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (set in the workflow env, NOT here: conftest deliberately never forces
  device counts, so the default leg's smoke tests see the one real CPU
  device).  Skips cleanly everywhere else.

Parity assertions are ``np.array_equal`` — BIT-exact, not allclose: the
sharded engine's contract is that sharding is invisible in the output
(see serving/_sharded.py for how collect_up's pinned summation tree, the
kernel's ``row_base`` mask, and the blocked row layout buy that).
"""
import jax
import numpy as np
import pytest

from repro.core.vdt import VariationalDualTree
from repro.serving import (EngineFleet, PropagateEngine, PropagateRequest,
                           ShardedPropagateEngine)

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (the CI sharded leg forces 8 host devices)")

ITERS = 8


@pytest.fixture(scope="module")
def fitted128():
    """(x, vdt) on n=128 gaussian data, enough leaves for an 8-way mesh."""
    r = np.random.RandomState(5)
    x = r.randn(128, 8).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 128, refine_batch=64)
    return x, vdt


def _requests(rng, n, count, backend="vdt", n_iters=ITERS):
    reqs = []
    for i in range(count):
        c = [1, 2, 3, 4][i % 4]
        y0 = (rng.rand(n, c) > 0.8).astype(np.float32)
        reqs.append(PropagateRequest(
            y0, alpha=[0.01, 0.05, 0.2][i % 3], n_iters=n_iters,
            backend=backend))
    return reqs


def _run(engine, reqs):
    futs = [engine.submit(q) for q in reqs]
    engine.flush()
    return [np.asarray(f.result(timeout=30)) for f in futs]


def _assert_bit_equal(got, want):
    for g, w in zip(got, want):
        assert g.shape == w.shape
        assert np.array_equal(g, w), float(np.abs(g - w).max())


# --------------------------------------------------------- D=1 (tier-1)
@pytest.mark.parametrize("backend", ["vdt", "exact"])
def test_single_device_mesh_bit_parity(fitted128, backend):
    """D=1 sharded engine == plain engine, bit for bit, both backends."""
    x, vdt = fitted128
    rng = np.random.RandomState(0)
    reqs = _requests(rng, x.shape[0], count=5, backend=backend)
    ref = PropagateEngine(vdt, start=False, max_batch=4)
    sh = ShardedPropagateEngine(vdt, devices=jax.devices()[:1],
                                start=False, max_batch=4)
    try:
        _assert_bit_equal(_run(sh, reqs), _run(ref, reqs))
    finally:
        ref.shutdown()
        sh.shutdown()


@pytest.mark.parametrize("backend", ["vdt", "exact"])
def test_single_device_segmented_edf_parity(fitted128, backend):
    """Segmented preemptible dispatch on the sharded engine resumes through
    the sharded carry and still reproduces the monolithic result exactly
    (n_iters=9 over segment_iters=2 forces a 1-iteration tail segment)."""
    x, vdt = fitted128
    rng = np.random.RandomState(1)
    reqs = _requests(rng, x.shape[0], count=4, backend=backend, n_iters=9)
    ref = PropagateEngine(vdt, start=False, max_batch=4)
    sh = ShardedPropagateEngine(vdt, devices=jax.devices()[:1],
                                start=False, max_batch=4,
                                policy="edf", segment_iters=2)
    try:
        assert "preempt" in sh.capabilities()
        _assert_bit_equal(_run(sh, reqs), _run(ref, reqs))
    finally:
        ref.shutdown()
        sh.shutdown()


def test_capabilities_surface(fitted128):
    """Capability introspection: sharded advertises {publish, sharded}
    (plus preempt only under the EDF/segmented config) and NEVER grf."""
    _, vdt = fitted128
    dev = jax.devices()[:1]
    sh = ShardedPropagateEngine(vdt, devices=dev, start=False)
    base = PropagateEngine(vdt, start=False)
    try:
        assert sh.capabilities() == frozenset({"publish", "sharded"})
        assert base.capabilities() == frozenset({"publish", "grf"})
    finally:
        sh.shutdown()
        base.shutdown()


def test_grf_rejected_at_ctor_and_submit(fitted128):
    x, vdt = fitted128
    with pytest.raises(ValueError, match="grf"):
        ShardedPropagateEngine(vdt, devices=jax.devices()[:1],
                               backend="grf", start=False)
    sh = ShardedPropagateEngine(vdt, devices=jax.devices()[:1], start=False)
    try:
        with pytest.raises(ValueError, match="grf"):
            sh.submit(PropagateRequest(
                np.zeros((x.shape[0], 1), np.float32), backend="grf"))
    finally:
        sh.shutdown()


def test_warmup_precompiles_sharded_executables(fitted128):
    _, vdt = fitted128
    sh = ShardedPropagateEngine(vdt, devices=jax.devices()[:1],
                                start=False, max_batch=2,
                                policy="edf", segment_iters=4)
    try:
        assert sh.warmup(widths=(2,), n_iters=(ITERS,)) > 0
    finally:
        sh.shutdown()


def test_publish_serves_new_epoch_single_device():
    """Publish on the sharded engine: the swapped-in tree serves bit-equal
    to a fresh engine over the same tree, and the retired epoch's device
    buffers are dropped from the cache."""
    from repro.core.streaming import insert_points

    r = np.random.RandomState(11)
    x = r.randn(96, 6).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 96, refine_batch=48,
                                  capacity=128)
    sh = ShardedPropagateEngine(vdt, devices=jax.devices()[:1],
                                start=False, max_batch=4)
    try:
        _run(sh, _requests(np.random.RandomState(2), 96, count=2))
        up = insert_points(vdt, x[:4] + 0.01)
        sh.publish(up.vdt, patched_points=up.patched_points)
        req = PropagateRequest((r.rand(sh.n, 2) > 0.8).astype(np.float32),
                               alpha=0.05, n_iters=ITERS)
        got = _run(sh, [req])[0]
        ref = PropagateEngine(up.vdt, start=False)
        try:
            want = _run(ref, [req])[0]
        finally:
            ref.shutdown()
        assert np.array_equal(got, want)
        assert len(sh._buf_cache) == 1  # old epoch's buffers retired
    finally:
        sh.shutdown()


def test_fleet_registers_sharded_tenant(fitted128):
    """engine_cls routes a tenant onto the sharded engine with ZERO other
    fleet changes; routing/DRR/publish all hold."""
    x, vdt = fitted128
    fleet = EngineFleet(start=False)
    try:
        eng = fleet.register("shard", vdt,
                             engine_cls=ShardedPropagateEngine,
                             devices=jax.devices()[:1], max_batch=4)
        assert isinstance(eng, ShardedPropagateEngine)
        reqs = [PropagateRequest(
            (np.random.RandomState(7).rand(x.shape[0], 2) > 0.8)
            .astype(np.float32), alpha=0.05, n_iters=ITERS, tenant="shard")]
        futs = [fleet.submit(q) for q in reqs]
        fleet.flush()
        ref = PropagateEngine(vdt, start=False)
        try:
            want = _run(ref, reqs)
        finally:
            ref.shutdown()
        _assert_bit_equal([np.asarray(f.result(timeout=30)) for f in futs],
                          want)
    finally:
        fleet.shutdown()


def test_fleet_publish_requires_capability(fitted128):
    """Fleet publish routes on the capability, not on hasattr: an engine
    that doesn't advertise 'publish' is refused with a clear error."""
    _, vdt = fitted128

    class _NoPublish(PropagateEngine):
        def capabilities(self):
            return super().capabilities() - {"publish"}

    fleet = EngineFleet(start=False)
    try:
        fleet.register("fixed", vdt, engine_cls=_NoPublish)
        with pytest.raises(ValueError, match="publish"):
            fleet.publish("fixed", vdt)
    finally:
        fleet.shutdown()


# ------------------------------------------------- multi-device (CI leg)
@multi_device
@pytest.mark.parametrize("backend", ["vdt", "exact"])
@pytest.mark.parametrize("seed", [0, 1])
def test_full_mesh_bit_parity(fitted128, backend, seed):
    """Full-mesh sharded engine == single-device engine over a mixed
    width/alpha request stream, bit for bit."""
    x, vdt = fitted128
    rng = np.random.RandomState(seed)
    reqs = _requests(rng, x.shape[0], count=6, backend=backend)
    ref = PropagateEngine(vdt, start=False, max_batch=4)
    sh = ShardedPropagateEngine(vdt, start=False, max_batch=4)
    try:
        assert sh.n_devices == jax.device_count()
        _assert_bit_equal(_run(sh, reqs), _run(ref, reqs))
    finally:
        ref.shutdown()
        sh.shutdown()


@multi_device
@pytest.mark.parametrize("backend", ["vdt", "exact"])
def test_full_mesh_segmented_edf_parity(fitted128, backend):
    """PR 6's carry guarantee survives sharding: EDF segmented dispatch on
    the full mesh is bit-identical to the monolithic single-device run."""
    x, vdt = fitted128
    rng = np.random.RandomState(3)
    reqs = _requests(rng, x.shape[0], count=4, backend=backend, n_iters=9)
    ref = PropagateEngine(vdt, start=False, max_batch=4)
    sh = ShardedPropagateEngine(vdt, start=False, max_batch=4,
                                policy="edf", segment_iters=2)
    try:
        _assert_bit_equal(_run(sh, reqs), _run(ref, reqs))
    finally:
        ref.shutdown()
        sh.shutdown()


@multi_device
def test_full_mesh_publish_mid_flight():
    """Queued old-epoch requests keep their bits across a publish; the new
    epoch serves bit-equal to a fresh engine on the full mesh."""
    from repro.core.streaming import insert_points

    r = np.random.RandomState(13)
    x = r.randn(128, 8).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 128, refine_batch=64,
                                  capacity=160)
    rng = np.random.RandomState(4)
    reqs = _requests(rng, 128, count=2)
    sh = ShardedPropagateEngine(vdt, start=False, max_batch=4)
    ref = PropagateEngine(vdt, start=False, max_batch=4)
    try:
        pending = [sh.submit(q) for q in reqs]
        up = insert_points(vdt, x[:4] + 0.01)
        sh.publish(up.vdt, patched_points=up.patched_points)
        req2 = PropagateRequest((r.rand(sh.n, 2) > 0.8).astype(np.float32),
                                alpha=0.05, n_iters=ITERS)
        f2 = sh.submit(req2)
        sh.flush()
        _assert_bit_equal(
            [np.asarray(f.result(timeout=30)) for f in pending],
            _run(ref, reqs))
        ref2 = PropagateEngine(up.vdt, start=False)
        try:
            want2 = _run(ref2, [req2])[0]
        finally:
            ref2.shutdown()
        assert np.array_equal(np.asarray(f2.result(timeout=30)), want2)
    finally:
        sh.shutdown()
        ref.shutdown()


@multi_device
def test_more_devices_than_leaves_rejected():
    r = np.random.RandomState(17)
    x = r.randn(3, 3).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=12)
    if jax.device_count() <= int(vdt.tree.n_leaves):
        pytest.skip("tree too large to trigger the leaf floor here")
    with pytest.raises(ValueError, match="leaf"):
        ShardedPropagateEngine(vdt, start=False)


@multi_device
def test_non_power_of_two_mesh_rejected(fitted128):
    _, vdt = fitted128
    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices to select a non-power-of-two subset")
    with pytest.raises(ValueError, match="power-of-two"):
        ShardedPropagateEngine(vdt, devices=jax.devices()[:3], start=False)
