"""Per-architecture smoke tests: reduced config, one forward + one train
step + one prefill/decode step on CPU; asserts shapes and finiteness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.transformer import init_lm, lm_forward
from repro.models.whisper import encdec_forward, init_encdec
from repro.serving.decode import decode_step, prefill
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step

B, S = 2, 32

# Tier-1 keeps one cheap representative per execution family (dense/decode
# and ssm); the full 10-arch sweep is the slow tier: `pytest -m ""`.
_TIER1_ARCHS = {"smollm-360m", "mamba2-130m"}
ARCH_PARAMS = [
    pytest.param(a, marks=() if a in _TIER1_ARCHS else (pytest.mark.slow,))
    for a in ARCH_IDS
]


def _init(cfg, key):
    if cfg.family == "audio":
        return init_encdec(cfg, key)
    return init_lm(cfg, key)


def _batch(cfg, rng, seq=S):
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, size=(B, seq + 1)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = get_smoke_config(arch)
    params = _init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    inp = batch["tokens"][:, :-1]
    if cfg.family == "audio":
        logits, aux = encdec_forward(params, inp, batch["frames"], cfg)
        want_s = S
    elif cfg.family == "vlm":
        logits, aux = lm_forward(params, inp, cfg, patches=batch["patches"])
        want_s = S + cfg.n_patches
    else:
        logits, aux = lm_forward(params, inp, cfg)
        want_s = S
    assert logits.shape == (B, want_s, cfg.padded_vocab), logits.shape
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_one_train_step_reduces_nothing_nan(arch, rng):
    cfg = get_smoke_config(arch)
    params = _init(cfg, jax.random.PRNGKey(1))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(state.step) == 1
    # params actually changed
    leaves0 = jax.tree_util.tree_leaves(params)
    leaves1 = jax.tree_util.tree_leaves(state.params)
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(leaves0, leaves1)
    )


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_decode_consistency(arch, rng):
    """Decode after prefill must produce logits close to the full forward
    pass at the same position (cache correctness)."""
    cfg = get_smoke_config(arch)
    params = _init(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]  # (B, S+1)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = batch["patches"]
    if cfg.family == "audio":
        kwargs["frames"] = batch["frames"]

    # prefill on S tokens, then decode token S
    logits_pre, state = prefill(params, tokens[:, :S], cfg, **kwargs)
    logits_dec, state2 = decode_step(params, tokens[:, S:S + 1], state, cfg)

    # full forward on S+1 tokens: position S-1 should match prefill's last,
    # position S should match decode's output
    inp = tokens
    if cfg.family == "audio":
        full, _ = encdec_forward(params, inp, batch["frames"], cfg)
        off = 0
    elif cfg.family == "vlm":
        full, _ = lm_forward(params, inp, cfg, patches=batch["patches"])
        off = cfg.n_patches
    else:
        full, _ = lm_forward(params, inp, cfg)
        off = 0

    ref_pre = np.asarray(full[:, off + S - 1], np.float32)
    got_pre = np.asarray(logits_pre, np.float32)
    np.testing.assert_allclose(got_pre, ref_pre, rtol=0.15, atol=0.15)

    ref_dec = np.asarray(full[:, off + S], np.float32)
    got_dec = np.asarray(logits_dec, np.float32)
    np.testing.assert_allclose(got_dec, ref_dec, rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """Full configs must match the assignment table exactly."""
    cfg = get_config(arch)
    table = {
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-130m": (24, 768, 12, 12, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    }
    l, d, h, kv, f, v = table[arch]
    assert cfg.n_layers == l and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == f and cfg.vocab_size == v
    if arch == "deepseek-moe-16b":
        assert cfg.n_experts == 64 and cfg.experts_per_token == 6
        assert cfg.n_shared_experts == 2
    if arch == "mixtral-8x7b":
        assert cfg.n_experts == 8 and cfg.experts_per_token == 2
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128


def test_param_counts_in_expected_range():
    """Sanity: derived parameter counts are in the ballpark of the names."""
    expect = {
        "gemma3-1b": (0.7e9, 1.6e9),
        "internlm2-1.8b": (1.4e9, 2.4e9),
        "glm4-9b": (8e9, 11e9),
        "smollm-360m": (0.25e9, 0.5e9),
        "zamba2-1.2b": (0.8e9, 1.7e9),
        "internvl2-1b": (0.4e9, 1.2e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "mixtral-8x7b": (42e9, 50e9),
        "mamba2-130m": (0.1e9, 0.22e9),
        # our whisper uses the framework-uniform gated MLP (3 mats vs 2) and
        # untied embeddings -> ~1.0B vs the 769M reference; dims/L/H match
        # the assignment table exactly (noted in DESIGN.md §5)
        "whisper-medium": (0.8e9, 1.15e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
