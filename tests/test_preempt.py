"""Preemptible segmented dispatch: bit-parity + engine preemption behavior.

Two layers under test:

* the segmented/resume scan primitives (``core.label_prop``): splitting an
  eq.-15 walk into carry-resumed segments must be BIT-identical to the
  monolithic scan, for both backends, any segment size, any batch/width —
  the property that makes preemption free of numerical consequences.  The
  model is rebuilt from the golden fixture so the parity grid is pinned to
  a deterministic fit;
* the engine's preemptible dispatch: a tight-deadline arrival landing
  mid-flight of a long segmented scan is served at the next segment
  boundary (instead of waiting out — and expiring behind — the whole
  scan), the suspended walk resumes bit-identically, and the
  ``preemptions`` / ``preempt_iters`` metrics record the yield.

The engine tests drive the deterministic scheduler (``start=False`` +
``step``) with a fake clock advanced by the dispatch itself, so preemption
decisions — which hinge on the measured per-iteration time — are
reproducible without real sleeps.
"""
import numpy as np
import pytest

from repro.core.label_prop import (lp_scan_fused, lp_scan_fused_segmented,
                                   lp_scan_leaforder,
                                   lp_scan_leaforder_segmented)
from repro.serving import PropagateEngine, PropagateRequest
from repro.serving._queue import QueueEntry, RequestQueue

ITERS = 13  # covers whole segments, a remainder, and a length-1 tail
SEGMENTS = (1, 2, 5, ITERS, ITERS + 7)  # incl. seg == and > n_iters


class FakeClock:
    """Deterministic time source (seconds)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@pytest.fixture(scope="module")
def golden_vdt():
    """Model refit from the golden fixture's data — a pinned parity anchor."""
    from repro.core.vdt import VariationalDualTree

    g = np.load("tests/golden_sqeuclidean.npz")
    x = g["x"]
    return x, VariationalDualTree.fit(x, max_blocks=4 * x.shape[0])


# ------------------------------------------------- scan-level bit-parity
@pytest.mark.parametrize("seg", SEGMENTS)
@pytest.mark.parametrize("width", [1, 3])
def test_leaforder_segmented_bit_identical(golden_vdt, seg, width):
    """lp_scan_leaforder_segmented == lp_scan_leaforder, exactly."""
    x, vdt = golden_vdt
    rng = np.random.RandomState(11)
    y0 = (rng.rand(x.shape[0], width) > 0.7).astype(np.float32)
    tree = vdt.tree
    a, b, _, q, mask = vdt._dispatch_buffers()
    y0_leaf = np.zeros((tree.n_leaves, width), np.float32)
    y0_leaf[np.asarray(tree.slot_of)] = y0
    alpha = np.float32(0.02)

    mono = np.asarray(lp_scan_leaforder(
        y0_leaf, mask, a, b, q, alpha, tree.L, ITERS))
    split = np.asarray(lp_scan_leaforder_segmented(
        y0_leaf, mask, a, b, q, alpha, tree.L, ITERS, seg))
    np.testing.assert_array_equal(mono, split)


@pytest.mark.parametrize("seg", SEGMENTS)
@pytest.mark.parametrize("shape", ["2d-1", "2d-3", "3d"])
def test_fused_segmented_bit_identical(golden_vdt, seg, shape):
    """lp_scan_fused_segmented == lp_scan_fused across the B x C grid.

    Includes the once-broken corner: a length-1 tail segment (e.g. 13 split
    by 2) used to drift 1 ulp because XLA constant-folds a static length-1
    scan into a differently-fused inline body; the resume primitives take
    the iteration count as a dynamic loop bound precisely so every segment
    runs the same while-loop executable.
    """
    x, vdt = golden_vdt
    rng = np.random.RandomState(13)
    if shape == "3d":
        y0 = rng.rand(2, x.shape[0], 2).astype(np.float32)
        alpha = np.array([0.01, 0.05], np.float32)  # per-request alphas
    else:
        width = int(shape.split("-")[1])
        y0 = rng.rand(x.shape[0], width).astype(np.float32)
        alpha = 0.02
    sigma = float(vdt.sigma)

    mono = np.asarray(lp_scan_fused(vdt.x_rows, y0, sigma, alpha, ITERS))
    split = np.asarray(lp_scan_fused_segmented(
        vdt.x_rows, y0, sigma, alpha, ITERS, segment_iters=seg))
    np.testing.assert_array_equal(mono, split)


@pytest.mark.parametrize("backend", ["vdt", "exact"])
def test_label_propagate_resume_chain_bit_identical(golden_vdt, backend):
    """Chained label_propagate_resume segments == one label_propagate.

    The exact call sequence the engine's preemptible dispatch makes —
    batched (B, N, C) stacks with per-request alpha, resuming through the
    row-order <-> leaf-order round trip on the vdt backend.
    """
    x, vdt = golden_vdt
    rng = np.random.RandomState(17)
    y0 = rng.rand(3, x.shape[0], 2).astype(np.float32)
    alpha = np.array([0.01, 0.05, 0.2], np.float32)

    mono = np.asarray(vdt.label_propagate(
        y0, alpha=alpha, n_iters=ITERS, batched=True, backend=backend))
    y, done = y0, 0
    while done < ITERS:
        k = min(4, ITERS - done)
        y = vdt.label_propagate_resume(
            np.asarray(y), y0, alpha=alpha, n_iters=k, batched=True,
            backend=backend)
        done += k
    np.testing.assert_array_equal(mono, np.asarray(y))


def test_segmented_rejects_bad_segment_iters(golden_vdt):
    x, vdt = golden_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    with pytest.raises(ValueError, match="segment_iters"):
        lp_scan_fused_segmented(vdt.x_rows, y0, float(vdt.sigma), 0.01, 4,
                                segment_iters=0)
    with pytest.raises(ValueError, match="carry shape"):
        vdt.label_propagate_resume(np.zeros((x.shape[0], 2), np.float32), y0)


# ------------------------------------------------------- queue urgency API
def test_queue_deadline_before_and_drain_urgent():
    clock = FakeClock()
    q = RequestQueue(16, discipline="edf", clock=clock)

    def entry(seq, deadline):
        from concurrent.futures import Future
        return QueueEntry(seq=seq, request=None, future=Future(),
                          t_submit=clock(), t_deadline=deadline)

    q.put(entry(0, 5.0))
    q.put(entry(1, 0.5))
    q.put(entry(2, None))
    assert q.deadline_before(1.0) and not q.deadline_before(0.5)

    # prefix drain: only the entry inside the horizon pops; heap order and
    # the deadline-less entry are untouched
    live, cancelled, expired = q.drain_urgent(8, horizon=1.0)
    assert [e.seq for e in live] == [1]
    assert not cancelled and not expired
    assert len(q) == 2 and q.next_deadline() == 5.0
    assert q.popped == 1  # the monotone pop counter saw exactly one pop

    # expired urgent entries fast-fail out of the urgent drain too
    clock.advance(10.0)
    live, cancelled, expired = q.drain_urgent(8, horizon=100.0)
    assert not live and [e.seq for e in expired] == [0]
    assert len(q) == 1  # deadline-less entry never drains urgently
    assert q.popped == 2


def test_drain_urgent_noop_outside_edf():
    q = RequestQueue(4, discipline="fifo")
    assert q.drain_urgent(4, horizon=1.0) == ([], [], [])
    assert not q.deadline_before(float("inf"))


# -------------------------------------------------- engine preemption path
class _InjectingVDT:
    """Proxy model: advances a fake clock per dispatch (so per-iteration
    time is measurable and deterministic) and submits an urgent request
    after the first segment — a mid-flight arrival, reproducibly."""

    ITER_S = 0.01  # simulated device seconds per LP iteration

    def __init__(self, inner, clock):
        self._inner = inner
        self._clock = clock
        self.engine = None
        self.urgent = None
        self.resume_calls = 0
        self.done_t: dict = {}  # fake-clock instants of future resolution

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def label_propagate(self, y0, *args, n_iters=500, **kw):
        self._clock.advance(self.ITER_S * n_iters)
        return self._inner.label_propagate(y0, *args, n_iters=n_iters, **kw)

    def label_propagate_resume(self, y, y0, *args, n_iters=500, **kw):
        self.resume_calls += 1
        self._clock.advance(self.ITER_S * n_iters)
        out = self._inner.label_propagate_resume(y, y0, *args,
                                                 n_iters=n_iters, **kw)
        if self.urgent is None:
            # first segment just finished: an urgent request lands NOW,
            # 35 iterations (~0.35s simulated) before the bulk scan ends
            self.urgent = self.engine.submit(PropagateRequest(
                y0=np.ones((y0.shape[-2], 1), np.float32), n_iters=5,
                deadline_ms=100.0))
            self.urgent.add_done_callback(
                lambda f: self.done_t.setdefault("urgent", self._clock()))
        return out


def test_midflight_urgent_arrival_preempts(small_fitted_vdt):
    """The tentpole behavior: a deadline-100ms request submitted one
    segment into a 40-iteration scan is served at the next segment
    boundary instead of expiring behind it, and the suspended scan's final
    answer is bit-identical to an unpreempted run."""
    x, vdt = small_fitted_vdt
    clock = FakeClock()
    proxy = _InjectingVDT(vdt, clock)
    eng = PropagateEngine(proxy, start=False, policy="edf", segment_iters=5,
                          clock=clock)
    proxy.engine = eng
    y0 = np.random.RandomState(23).rand(x.shape[0], 2).astype(np.float32)
    bulk = eng.submit(PropagateRequest(y0=y0, alpha=0.02, n_iters=40,
                                       deadline_ms=60_000.0))

    bulk.add_done_callback(
        lambda f: proxy.done_t.setdefault("bulk", clock()))
    eng.step()

    m = eng.metrics()
    # without preemption the urgent request (deadline 0.1s) could not have
    # survived the remaining 35 iterations (~0.35s simulated): it would
    # have expired in the post-scan drain.  Instead it completed, in time.
    assert proxy.urgent.result(timeout=0) is not None
    assert m.expired == 0 and m.completed == 2
    assert m.preemptions == 1
    assert m.preempt_iters == 35  # 40 - one 5-iteration segment
    # the urgent answer resolved mid-scan, not after the bulk walk
    assert proxy.done_t["urgent"] < proxy.done_t["bulk"]

    # the preempted walk is bit-identical to a never-preempted one
    mono = vdt.label_propagate(y0, alpha=0.02, n_iters=40)
    np.testing.assert_array_equal(np.asarray(bulk.result(timeout=0)),
                                  np.asarray(mono))
    eng.shutdown()


def test_no_preemption_without_urgency(small_fitted_vdt):
    """Segmented dispatch without a threatened deadline never yields, and
    segmenting under a deadline-less queue costs no correctness."""
    x, vdt = small_fitted_vdt
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="edf", segment_iters=4,
                          clock=clock)
    y0 = np.random.RandomState(29).rand(x.shape[0], 1).astype(np.float32)
    fut = eng.submit(PropagateRequest(y0=y0, n_iters=9))
    eng.step()
    m = eng.metrics()
    assert m.preemptions == 0 and m.preempt_iters == 0
    np.testing.assert_array_equal(
        np.asarray(fut.result(timeout=0)),
        np.asarray(vdt.label_propagate(y0, n_iters=9)))
    eng.shutdown()


def test_segmenting_inert_outside_edf(small_fitted_vdt):
    """segment_iters under fifo stays monolithic (no urgency signal): the
    resume path is never entered."""
    x, vdt = small_fitted_vdt

    calls = []
    real = vdt.label_propagate_resume

    class Spy:
        def __getattr__(self, name):
            return getattr(vdt, name)

        def label_propagate_resume(self, *a, **kw):
            calls.append(1)
            return real(*a, **kw)

    eng = PropagateEngine(Spy(), start=False, policy="fifo", segment_iters=2)
    fut = eng.submit(PropagateRequest(
        y0=np.zeros((x.shape[0], 1), np.float32), n_iters=8))
    eng.step()
    assert fut.result(timeout=0) is not None and not calls
    eng.shutdown()


def test_engine_rejects_bad_segment_iters(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    with pytest.raises(ValueError, match="segment_iters"):
        PropagateEngine(vdt, start=False, segment_iters=0)
