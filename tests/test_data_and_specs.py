"""Data pipeline determinism/shardability and input-spec coverage."""
import numpy as np
import pytest

import jax

from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cell_is_applicable, input_specs
from repro.data.pipeline import TokenPipeline
from repro.data.synthetic import by_name


def test_token_pipeline_deterministic():
    p = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(a, b)
    c = p.batch(6)
    assert not np.array_equal(a, c)
    assert a.shape == (8, 17) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 1000


def test_token_pipeline_sharding_partitions_global_batch():
    """Union of host shards == semantics: each host's rows deterministic and
    disjoint in randomness (host index enters the seed)."""
    p = TokenPipeline(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    h0 = p.batch(3, host=0, n_hosts=2)
    h1 = p.batch(3, host=1, n_hosts=2)
    assert h0.shape == (4, 9) and h1.shape == (4, 9)
    assert not np.array_equal(h0, h1)
    # re-computation for replay gives identical shards
    np.testing.assert_array_equal(h0, p.batch(3, host=0, n_hosts=2))


@pytest.mark.parametrize("name", ["blobs", "moons", "digit1", "usps"])
def test_synthetic_datasets_deterministic(name):
    kw = dict(n=200) if name != "blobs" else dict(n=200, d=4)
    a = by_name(name, **kw)
    b = by_name(name, **kw)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.labels, b.labels)
    assert a.x.dtype == np.float32
    assert set(np.unique(a.labels)) <= set(range(a.n_classes))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_cover_all_cells(arch, shape):
    """Every applicable cell must produce well-formed ShapeDtypeStructs."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    ok, why = cell_is_applicable(cfg, sp)
    if not ok:
        assert "sub-quadratic" in why
        return
    kwargs, meta = input_specs(cfg, sp)
    assert meta["tokens_per_step"] > 0
    leaves = jax.tree_util.tree_leaves(kwargs)
    assert leaves, (arch, shape)
    for leaf in leaves:
        assert isinstance(leaf, jax.ShapeDtypeStruct)
        assert all(d > 0 for d in leaf.shape)
    if sp.kind == "train":
        toks = kwargs["batch"]["tokens"]
        assert toks.shape[0] == sp.global_batch
    if sp.kind == "decode":
        assert kwargs["token"].shape == (sp.global_batch, 1)


def test_long_context_rules_match_design():
    """DESIGN.md §5: long_500k runs for ssm/hybrid/pure-SWA only."""
    runs = {a for a in ARCH_IDS
            if cell_is_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"mamba2-130m", "zamba2-1.2b", "mixtral-8x7b"}
