"""Property-based harness for the Bregman divergence registry.

Three layers of guarantees:

1. **Bregman axioms** (via the ``tests/_hyp`` shim — real hypothesis when
   installed, the deterministic fallback sampler otherwise) for every
   registered divergence: non-negativity, identity of indiscernibles, and
   convexity in the first argument.
2. **Block factorization** — the O(1)-per-block subtree-statistics form
   equals the brute-force pairwise double sum on real nodes.
3. **sqeuclidean bit-parity** — the default divergence path reproduces the
   pre-Bregman implementation bit-for-bit on the committed golden fixture
   (``tests/golden_sqeuclidean.npz``, generated from the pre-PR code on the
   ``small_fitted_vdt`` seed data).

Plus the domain-mismatch contract: KL/Itakura-Saito over non-positive data
raise a clear ``ValueError`` (message pinned) instead of emitting NaNs.
"""
import pathlib

import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.divergence import (DIVERGENCES, bind_divergence,
                                   get_divergence, mahalanobis,
                                   resolve_divergence)
from repro.core.qopt import block_sq_dists, lower_bound, optimize_q
from repro.core.tree import build_tree, leaf_range
from repro.core.vdt import VariationalDualTree

GOLDEN = pathlib.Path(__file__).parent / "golden_sqeuclidean.npz"

# every registered divergence plus a non-trivially-scaled Mahalanobis —
# the axioms and factorization must hold for all of them
ALL_DIVS = sorted(DIVERGENCES) + ["mahalanobis-scaled"]


def _div(name: str, d: int):
    if name == "mahalanobis-scaled":
        return mahalanobis(np.linspace(0.5, 2.0, d))
    return get_divergence(name)


def _points(rng, n: int, d: int) -> np.ndarray:
    """Points inside every registered divergence's domain (positive orthant)."""
    return (rng.rand(n, d).astype(np.float32) + 0.1) * 2.0


# ------------------------------------------------------------ Bregman axioms
@pytest.mark.parametrize("name", ALL_DIVS)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_non_negativity(name, seed):
    rng = np.random.RandomState(seed)
    d = 4
    div = _div(name, d)
    a = jnp.asarray(_points(rng, 7, d))
    b = jnp.asarray(_points(rng, 5, d))
    pw = np.asarray(div.pairwise(a, b))
    assert np.isfinite(pw).all()
    assert (pw >= 0.0).all()


@pytest.mark.parametrize("name", ALL_DIVS)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_identity_of_indiscernibles(name, seed):
    rng = np.random.RandomState(seed)
    d = 3
    div = _div(name, d)
    x = jnp.asarray(_points(rng, 6, d))
    pw = np.asarray(div.pairwise(x, x))
    # d(a, a) == 0 ...
    np.testing.assert_allclose(np.diagonal(pw), 0.0, atol=5e-5)
    # ... and d(a, b) > 0 for the distinct random points off the diagonal
    off = pw[~np.eye(pw.shape[0], dtype=bool)]
    assert (off > 1e-7).all()


@pytest.mark.parametrize("name", ALL_DIVS)
@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    lam=st.floats(min_value=0.05, max_value=0.95),
)
def test_convexity_in_first_argument(name, seed, lam):
    """d(lam*a1 + (1-lam)*a2, b) <= lam*d(a1, b) + (1-lam)*d(a2, b)."""
    rng = np.random.RandomState(seed)
    d = 4
    div = _div(name, d)
    a1 = jnp.asarray(_points(rng, 1, d))
    a2 = jnp.asarray(_points(rng, 1, d))
    b = jnp.asarray(_points(rng, 8, d))
    mix = lam * a1 + (1.0 - lam) * a2
    lhs = np.asarray(div.pairwise(mix, b))[0]
    rhs = (lam * np.asarray(div.pairwise(a1, b))
           + (1.0 - lam) * np.asarray(div.pairwise(a2, b)))[0]
    assert (lhs <= rhs + 1e-4 * (1.0 + np.abs(rhs))).all()


@pytest.mark.parametrize("name", ALL_DIVS)
def test_generator_consistency(name, rng):
    """pairwise == phi(a) - phi(b) - <grad phi(b), a - b> (the definition)."""
    d = 5
    div = _div(name, d)
    a = jnp.asarray(_points(rng, 6, d))
    b = jnp.asarray(_points(rng, 4, d))
    got = np.asarray(div.pairwise(a, b))
    phi_a = np.asarray(div.phi(a))
    phi_b = np.asarray(div.phi(b))
    gb = np.asarray(div.grad_phi(b))
    want = (phi_a[:, None] - phi_b[None, :]
            - np.einsum("nd,md->mn", gb, np.asarray(a))
            + np.einsum("nd,nd->n", gb, np.asarray(b))[None, :])
    np.testing.assert_allclose(got, np.maximum(want, 0.0), rtol=2e-4, atol=2e-5)


# ----------------------------------------------------- block factorization
@pytest.mark.parametrize("name", ALL_DIVS)
def test_block_div_matches_brute_force(name, rng):
    """The O(1) subtree-statistics factorization == the pairwise double sum."""
    d = 4
    x = _points(rng, 21, d)  # non-power-of-two: ghosts must stay invisible
    tree = build_tree(x)
    div = _div(name, d)
    bd = bind_divergence(div, tree)

    w = np.asarray(tree.w_leaf)
    xl = np.asarray(tree.x_leaf)
    real = w > 0
    ids_a = [0, 1, 3, 5, 8, 17, 33]
    ids_b = [2, 4, 6, 7, 9, 18, 34]
    got = np.asarray(bd.block_div(tree, jnp.asarray(ids_a), jnp.asarray(ids_b)))
    for k, (ai, bi) in enumerate(zip(ids_a, ids_b)):
        alo, ahi = leaf_range(ai, tree.L)
        blo, bhi = leaf_range(bi, tree.L)
        ia = np.arange(alo, ahi)[real[alo:ahi]]
        ib = np.arange(blo, bhi)[real[blo:bhi]]
        pw = np.asarray(div.pairwise(jnp.asarray(xl[ia]), jnp.asarray(xl[ib])))
        want = (w[ia][:, None] * w[None, ib] * pw).sum()
        np.testing.assert_allclose(got[k], want, rtol=2e-4, atol=1e-4)


def test_identity_mahalanobis_matches_sqeuclidean(rng):
    """scale == 1 Mahalanobis runs the *generic* Bregman-stats path, so its
    agreement with the special-cased sqeuclidean formula cross-checks both."""
    x = _points(rng, 19, 3)
    tree = build_tree(x)
    a = jnp.asarray([0, 1, 5, 9])
    b = jnp.asarray([2, 4, 6, 10])
    d_sq = np.asarray(block_sq_dists(tree, a, b))
    d_mh = np.asarray(block_sq_dists(tree, a, b, divergence="mahalanobis"))
    np.testing.assert_allclose(d_mh, d_sq, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["kl", "itakura_saito", "mahalanobis-scaled"])
def test_fit_and_row_stochastic(name, rng):
    """End-to-end fit under each non-default divergence: Q stays a proper
    row-stochastic transition matrix (eq. 16 is divergence-independent)."""
    d = 4
    x = _points(rng, 23, d)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 23, divergence=_div(name, d))
    dense = vdt.dense_q()
    np.testing.assert_allclose(dense.sum(1), np.ones(23), rtol=5e-5)
    assert np.isfinite(float(vdt.bound))
    assert vdt.divergence_name == _div(name, d).name


def test_singleton_blocks_equal_pairwise_softmax(rng):
    """Fully-refined KL blocks: q equals the exact Bregman softmax (the
    generalization of the paper's fully-refined-limit exactness)."""
    from repro.core.blocks import BlockPartition, densify_q
    from repro.kernels.fused_lp.ref import dense_transition_ref

    n, d = 12, 3
    x = _points(np.random.RandomState(5), n, d)
    tree = build_tree(x)
    w = np.asarray(tree.w_leaf)
    real = np.where(w > 0)[0]
    first_leaf = tree.n_internal
    a, b = [], []
    for s in real:
        for t in real:
            if s != t:
                a.append(first_leaf + s)
                b.append(first_leaf + t)
    m = len(a)
    bp = BlockPartition(a=np.asarray(a, np.int32), b=np.asarray(b, np.int32),
                        mirror=np.full(m, -1, np.int32),
                        active=np.ones(m, bool), n=m, cap=m)
    sigma = jnp.asarray(0.7)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), sigma, divergence="kl")
    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    dense = densify_q(bp, tree, q)
    p = np.asarray(dense_transition_ref(jnp.asarray(x), sigma, divergence="kl"))
    np.testing.assert_allclose(dense, p, rtol=1e-3, atol=1e-5)


# ------------------------------------------------- sqeuclidean bit-parity
def test_sqeuclidean_block_dists_bit_parity_with_golden(rng):
    """block_sq_dists (default AND named sqeuclidean) is bit-identical to the
    pre-Bregman implementation's output on the committed golden fixture."""
    g = np.load(GOLDEN)
    tree = build_tree(g["x"])
    a, b = jnp.asarray(g["a"]), jnp.asarray(g["b"])
    np.testing.assert_array_equal(np.asarray(block_sq_dists(tree, a, b)),
                                  g["block_d2"])
    np.testing.assert_array_equal(
        np.asarray(block_sq_dists(tree, a, b, divergence="sqeuclidean")),
        g["block_d2"])


def test_sqeuclidean_fit_bit_parity_with_golden():
    """The full default fit — q-state, bound, sigma, dense Q — reproduces the
    pre-PR outputs bit-for-bit (the acceptance pin for the generalization)."""
    g = np.load(GOLDEN)
    vdt = VariationalDualTree.fit(g["x"], max_blocks=4 * g["x"].shape[0])
    np.testing.assert_array_equal(np.asarray(vdt.qstate.log_q), g["log_q"])
    np.testing.assert_array_equal(np.asarray(vdt.qstate.log_v), g["log_v"])
    np.testing.assert_array_equal(np.asarray(vdt.qstate.log_z), g["log_z"])
    np.testing.assert_array_equal(np.asarray(vdt.qstate.log_zt), g["log_zt"])
    np.testing.assert_array_equal(np.asarray(vdt.qstate.bound), g["bound"])
    np.testing.assert_array_equal(np.asarray(vdt.sigma), g["sigma"])
    np.testing.assert_array_equal(vdt.dense_q(), g["dense_q"])
    # and the explicit name spells the same path
    vdt2 = VariationalDualTree.fit(g["x"], max_blocks=4 * g["x"].shape[0],
                                   divergence="sqeuclidean")
    np.testing.assert_array_equal(np.asarray(vdt2.qstate.log_q), g["log_q"])
    np.testing.assert_array_equal(np.asarray(vdt2.qstate.bound), g["bound"])


# -------------------------------------------------- domain mismatch errors
def test_fit_kl_on_nonpositive_data_raises(rng):
    x = rng.randn(16, 3).astype(np.float32)  # has negative coordinates
    with pytest.raises(ValueError, match="requires strictly positive inputs"):
        VariationalDualTree.fit(x, divergence="kl")


def test_lower_bound_divergence_domain_mismatch_raises(rng):
    """qopt.lower_bound with a positive-domain divergence over a tree fitted
    on signed data must raise, not return NaN."""
    x = rng.randn(16, 3).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 16)  # default fit is fine
    a, b = jnp.asarray(vdt.bp.a), jnp.asarray(vdt.bp.b)
    act = jnp.asarray(vdt.bp.active)
    with pytest.raises(ValueError, match="requires strictly positive inputs"):
        lower_bound(vdt.tree, a, b, act, vdt.qstate.log_q, vdt.sigma,
                    divergence="itakura_saito")


def test_dense_q_rejects_nonfinite_state(rng):
    """A hand-corrupted q-state (the NaN signature of a divergence/domain
    mismatch) surfaces as a clear ValueError from dense_q, never NaN output."""
    x = _points(rng, 16, 3)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 16, divergence="kl")
    vdt.qstate = vdt.qstate._replace(bound=jnp.asarray(float("nan")))
    with pytest.raises(ValueError, match="divergence/domain mismatch"):
        vdt.dense_q()
    with pytest.raises(ValueError, match="divergence/domain mismatch"):
        vdt.lower_bound()


def test_mahalanobis_equal_scales_share_identity():
    """Two factory calls with the same scale must compare/hash equal — the
    static jit key dedups on the digest-embedding name, so per-request
    factory construction can never grow the kernel compile cache."""
    a = mahalanobis([0.5, 2.0, 1.5])
    b = mahalanobis([0.5, 2.0, 1.5])
    c = mahalanobis([0.5, 2.0, 1.6])
    assert a == b and hash(a) == hash(b)
    assert a != c and a.name != c.name
    # names imply behavior: a length-k ones vector pins required_dim=k, so
    # it must NOT collide with the dimension-free registered "mahalanobis"
    ones3 = mahalanobis([1.0, 1.0, 1.0])
    assert ones3.name != "mahalanobis" and ones3.required_dim == 3
    assert mahalanobis([1.0]).name == "mahalanobis"


def test_mahalanobis_dim_mismatch_raises(rng):
    """A scale vector whose length disagrees with the data dimension fails
    at fit time with a clear error, not an opaque jit broadcast error."""
    x = _points(rng, 16, 4)
    with pytest.raises(ValueError, match="3-dimensional points, got d=4"):
        VariationalDualTree.fit(x, divergence=mahalanobis([1.0, 2.0, 3.0]))


def test_mahalanobis_scalar_scale_log_partition_counts_dim():
    """A length-1 scale broadcasts over all d coordinates, so its normalizer
    term must count d times (the anisotropic-Gaussian normalizer)."""
    import jax.numpy as jnp_

    dim, sigma = 4, 1.3
    gauss = 0.5 * dim * np.log(2.0 * np.pi * sigma * sigma)
    got_scalar = float(mahalanobis([2.0]).log_partition(dim, jnp_.asarray(sigma)))
    got_vector = float(mahalanobis([2.0] * dim).log_partition(dim, jnp_.asarray(sigma)))
    want = gauss - 0.5 * dim * np.log(2.0)
    np.testing.assert_allclose(got_scalar, want, rtol=1e-6)
    np.testing.assert_allclose(got_vector, want, rtol=1e-6)


def test_sigma_star_is_stationary_point_of_bound():
    """Eq. 12 must maximize the (surrogate) bound in sigma for KL too —
    fit_sigma_q stays coordinate ascent under every registered divergence."""
    from repro.core.qopt import lower_bound as lb
    from repro.core.sigma import sigma_star

    x = _points(np.random.RandomState(2), 20, 3)
    tree = build_tree(x)
    from repro.core.blocks import coarsest_partition
    bp = coarsest_partition(tree)
    a, b = jnp.asarray(bp.a), jnp.asarray(bp.b)
    act = jnp.asarray(bp.active)
    qs = optimize_q(tree, a, b, act, jnp.asarray(0.5), divergence="kl")
    s_star = sigma_star(tree, a, b, act, qs.log_q, divergence="kl")
    base = float(lb(tree, a, b, act, qs.log_q, s_star, divergence="kl"))
    for mult in (0.8, 1.25):
        other = float(lb(tree, a, b, act, qs.log_q, s_star * mult,
                         divergence="kl"))
        assert other <= base + 1e-4 * abs(base), (mult, other, base)


def test_bind_divergence_memoizes_per_tree(rng):
    """Repeated public-API calls with an unbound divergence must reuse the
    bound stats (one O(N d) pass per (divergence, tree), not per call),
    and fit itself seeds the memo."""
    x = _points(rng, 17, 3)
    tree = build_tree(x)
    b1 = bind_divergence("kl", tree)
    b2 = bind_divergence("kl", tree)
    assert b1 is b2
    other = build_tree(_points(rng, 17, 3))
    assert bind_divergence("kl", other) is not b1
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 17, divergence="kl")
    assert bind_divergence("kl", vdt.tree) is vdt.bound_divergence


def test_bound_divergence_rejects_wrong_tree(rng):
    """Stats bound to one tree must not silently combine with another
    equal-shaped tree's W/S1 — that would be finite but wrong."""
    t1 = build_tree(_points(rng, 17, 3))
    t2 = build_tree(_points(rng, 17, 3))  # same shape, different data
    b1 = bind_divergence("kl", t1)
    with pytest.raises(ValueError, match="bound to a different tree"):
        b1.block_div(t2, jnp.asarray([0]), jnp.asarray([1]))


def test_unknown_divergence_name_raises():
    with pytest.raises(ValueError, match="unknown divergence"):
        resolve_divergence("wasserstein")
    with pytest.raises(TypeError):
        resolve_divergence(1.5)
    with pytest.raises(ValueError, match="strictly positive"):
        mahalanobis([1.0, -2.0])


def test_vdt_lower_bound_matches_qopt(rng):
    """VariationalDualTree.lower_bound == optimize_q's internal bound, for a
    non-default divergence too."""
    x = _points(rng, 20, 3)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 20, divergence="kl")
    direct = float(vdt.lower_bound())
    assert np.isclose(direct, float(vdt.bound), rtol=1e-4), (direct, vdt.bound)
