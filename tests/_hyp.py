"""Hypothesis shim: real `hypothesis` when installed, tiny fallback otherwise.

The seed suite hard-imported `hypothesis`, so a clean environment could not
even COLLECT four test modules.  Property tests now import from here:

    from _hyp import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is available these are the real thing.  Otherwise `given`
degrades to a deterministic sampler: it draws `FALLBACK_EXAMPLES` pseudo-
random examples per test from the declared strategies (seeded from the
test's own module/qualname, so every test explores a DIFFERENT part of the
strategy space yet failures still reproduce) and runs the test body once
per draw.  Only the strategy surface
this repo uses is implemented (`st.integers`, `st.floats`); extend as needed.
No shrinking, no database — it is a smoke net, not a replacement.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sampler):
            self.sampler = sampler

    class st:  # noqa: N801  (mimics `hypothesis.strategies` module surface)
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    def settings(max_examples=None, deadline=None, **_kw):
        # examples are capped at FALLBACK_EXAMPLES regardless, to bound
        # tier-1 wall clock; the real hypothesis honors max_examples.
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # per-test seed: a shared constant would make every test draw
            # the SAME example sequence, so tests with identical strategy
            # declarations would all probe identical points of the space
            seed = zlib.crc32(
                f"{fn.__module__}::{fn.__qualname__}".encode()) & 0x7FFFFFFF

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.RandomState(seed)
                for _ in range(FALLBACK_EXAMPLES):
                    draw = {k: s.sampler(rng) for k, s in strategies.items()}
                    fn(*args, **draw, **kwargs)

            # hide the drawn params from pytest, which would otherwise try
            # to resolve them as fixtures (real hypothesis does the same)
            import inspect
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
