"""Incremental-vs-refit differential harness for the streaming VDT layer.

The core claim of ``core/streaming.py`` is an *equivalence*: a model mutated
through O(k d log N) insert/delete patches must be indistinguishable from a
model whose subtree statistics, block coverage, and q distribution were
recomputed from scratch on the final point set.  Every test here is an
instance of that claim:

* ``recompute(model)`` — the in-structure oracle (same tree, same block
  partition, full non-incremental stats + q optimization) — must agree with
  the patched model on stats, log_q, dense Q, and label propagation, for
  EVERY registered divergence and for interleaved insert/delete sequences.
* The ``exact`` LP backend depends only on ``x_rows`` and sigma, so the
  mutated model must match a true ``VariationalDualTree.fit`` of the final
  point set bit-for-bit on the exact backend — pinning the row-compaction
  ordering contract, not just the approximation.
* Edge cases: deletes that empty a whole subtree (its stats must hit exact
  zero and its blocks must deactivate), inserts into the emptied region
  (blocks must reactivate), a model shrunk to a single point, capacity
  exhaustion, and copy-on-write isolation of the source epoch.
"""
import numpy as np
import pytest

import jax

from repro.core import CapacityError
from repro.core.streaming import recompute
from repro.core.vdt import VariationalDualTree

DIVERGENCES = ("sqeuclidean", "kl", "itakura_saito", "mahalanobis")

N0 = 37          # odd: the fitted tree starts with ghost leaves of its own
DIM = 3
CAPACITY = 64
MAX_BLOCKS = 120


def make_x(rng, k, divergence, scale=1.0):
    x = rng.randn(k, DIM).astype(np.float32) * scale
    if divergence in ("kl", "itakura_saito"):
        x = np.abs(x) + 0.1  # positive-domain divergences
    return x.astype(np.float32)


@pytest.fixture(scope="module", params=DIVERGENCES)
def fitted(request):
    """(divergence, rng, fitted model with insert headroom) per divergence."""
    div = request.param
    rng = np.random.RandomState(11)
    x = make_x(rng, N0, div)
    vdt = VariationalDualTree.fit(x, max_blocks=MAX_BLOCKS, capacity=CAPACITY,
                                  divergence=div)
    return div, rng, vdt


def assert_matches_recompute(vdt, lp_atol=2e-3, unit_weights=True):
    """The differential oracle: patched model == from-scratch recompute."""
    ora = recompute(vdt)
    n = vdt.tree.n_points

    # subtree statistics (float64 patches vs float32 bottom-up sums)
    w_scale = max(1.0, float(np.abs(np.asarray(ora.tree.W)).max()))
    for name in ("W", "S1", "S2"):
        np.testing.assert_allclose(
            np.asarray(getattr(vdt.tree, name)),
            np.asarray(getattr(ora.tree, name)),
            rtol=2e-4, atol=1e-3 * w_scale, err_msg=f"stat {name} diverged")

    # block coverage is a pure function of the weights: must match exactly
    np.testing.assert_array_equal(vdt.bp.active, ora.bp.active)

    # the incremental q re-optimization must land on the same optimum
    mask = np.isfinite(np.asarray(ora.qstate.log_q))
    np.testing.assert_array_equal(np.isfinite(np.asarray(vdt.qstate.log_q)),
                                  mask)
    np.testing.assert_allclose(
        np.asarray(vdt.qstate.log_q)[mask], np.asarray(ora.qstate.log_q)[mask],
        rtol=1e-3, atol=5e-4, err_msg="log_q diverged from recompute")

    # dense Q equal to the oracle's; rows are stochastic for unit weights
    # (a weighted point's outgoing mass scales with its weight)
    q_mut, q_ora = vdt.dense_q(), ora.dense_q()
    if unit_weights:
        np.testing.assert_allclose(q_mut.sum(1), np.ones(n), atol=1e-3)
    np.testing.assert_allclose(q_mut, q_ora, atol=1e-4)

    # label propagation on the approximate backend
    r = np.random.RandomState(5)
    y0 = (r.rand(n, 2) > 0.8).astype(np.float32)
    lp_mut = np.asarray(vdt.label_propagate(y0, alpha=0.1, n_iters=8))
    lp_ora = np.asarray(ora.label_propagate(y0, alpha=0.1, n_iters=8))
    np.testing.assert_allclose(lp_mut, lp_ora, atol=lp_atol)


def apply_ops(vdt, rng, div, ops, x_mirror):
    """Run an insert/delete script, maintaining a host row mirror."""
    for kind, k in ops:
        n = vdt.tree.n_points
        if kind == "ins":
            x_new = make_x(rng, k, div)
            upd = vdt.insert_points(x_new)
            assert np.array_equal(upd.rows, np.arange(n, n + k))
            assert upd.row_map is None
            x_mirror = np.vstack([x_mirror, x_new])
        else:
            rows = np.sort(rng.choice(n, k, replace=False))
            upd = vdt.delete_points(rows)
            assert np.array_equal(upd.rows, rows)
            # row_map: -1 at deleted rows, order-preserving elsewhere
            rm = upd.row_map
            assert np.all(rm[rows] == -1)
            keep = np.setdiff1d(np.arange(n), rows)
            assert np.array_equal(rm[keep], np.arange(keep.size))
            x_mirror = np.delete(x_mirror, rows, axis=0)
        assert upd.patched_points == k
        vdt = upd.vdt
        # row bookkeeping is exact at every step, not just at the end
        np.testing.assert_array_equal(np.asarray(vdt.x_rows), x_mirror)
    return vdt, x_mirror


# ------------------------------------------------------- the differential
def test_interleaved_ops_match_recompute(fitted):
    """Interleaved inserts/deletes == from-scratch recompute, per divergence."""
    div, rng, vdt0 = fitted
    x0 = np.asarray(vdt0.x_rows).copy()
    ops = [("ins", 6), ("del", 9), ("ins", 4), ("del", 5), ("ins", 7),
           ("del", 3), ("ins", 2)]
    vdt, x_mirror = apply_ops(vdt0, np.random.RandomState(23), div, ops, x0)
    assert vdt.tree.n_points == N0 + 6 - 9 + 4 - 5 + 7 - 3 + 2
    assert_matches_recompute(vdt)


def test_single_insert_and_delete_match_recompute(fitted):
    """One-op mutations (the common serving case) hit the same optimum."""
    div, rng, vdt0 = fitted
    upd = vdt0.insert_points(make_x(np.random.RandomState(1), 5, div))
    assert upd.touched_blocks > 0 and upd.stale_blocks >= upd.touched_blocks
    assert_matches_recompute(upd.vdt)

    upd2 = upd.vdt.delete_points([0, 3, N0 + 2])
    assert_matches_recompute(upd2.vdt)


def test_exact_backend_matches_true_refit(fitted):
    """Row compaction makes the mutated model's exact-LP equal a real refit.

    The ``exact`` backend uses only ``x_rows`` and sigma, so if the
    streaming layer keeps surviving rows in relative order and appends
    inserts, the mutated model and ``fit()`` on the final point set are the
    SAME exact computation.
    """
    div, rng, vdt0 = fitted
    sigma = float(vdt0.sigma)
    rng2 = np.random.RandomState(31)
    upd = vdt0.delete_points(np.sort(rng2.choice(N0, 8, replace=False)))
    x_new = make_x(rng2, 6, div)
    vdt = upd.vdt.insert_points(x_new).vdt

    x_final = np.asarray(vdt.x_rows)
    refit = VariationalDualTree.fit(x_final, max_blocks=MAX_BLOCKS,
                                    sigma=sigma, learn_sigma=False,
                                    divergence=div)
    n = x_final.shape[0]
    y0 = (np.random.RandomState(9).rand(n, 2) > 0.8).astype(np.float32)
    lp_mut = np.asarray(vdt.label_propagate(y0, alpha=0.1, n_iters=6,
                                            backend="exact"))
    lp_ref = np.asarray(refit.label_propagate(y0, alpha=0.1, n_iters=6,
                                              backend="exact"))
    np.testing.assert_allclose(lp_mut, lp_ref, atol=1e-5)
    # and the approximate backend stays close to its own refit
    lp_vdt = np.asarray(vdt.label_propagate(y0, alpha=0.1, n_iters=6))
    assert np.all(np.isfinite(lp_vdt))


def test_copy_on_write_isolation(fitted):
    """Mutations never touch the source epoch: old model stays bit-identical."""
    div, rng, vdt0 = fitted
    y0 = (np.random.RandomState(2).rand(N0, 2) > 0.8).astype(np.float32)
    before_lp = np.asarray(vdt0.label_propagate(y0, alpha=0.1, n_iters=6)).copy()
    before_x = np.asarray(vdt0.x_rows).copy()
    before_q = np.asarray(vdt0.qstate.log_q).copy()

    upd = vdt0.insert_points(make_x(np.random.RandomState(3), 4, div))
    upd.vdt.delete_points([1, 2])

    assert vdt0.tree.n_points == N0
    np.testing.assert_array_equal(np.asarray(vdt0.x_rows), before_x)
    np.testing.assert_array_equal(np.asarray(vdt0.qstate.log_q), before_q)
    after_lp = np.asarray(vdt0.label_propagate(y0, alpha=0.1, n_iters=6))
    np.testing.assert_array_equal(after_lp, before_lp)


# ------------------------------------------------------------- edge cases
def test_delete_empties_subtree_exactly():
    """Deleting every point under a node zeroes its stats with NO residue."""
    rng = np.random.RandomState(7)
    x = make_x(rng, 24, "sqeuclidean")
    vdt = VariationalDualTree.fit(x, max_blocks=80, capacity=32)
    tree = vdt.tree
    L = tree.L
    # rows living in the leftmost quarter of the leaf array share the
    # depth-2 ancestor node 3 (heap ids: root 0, children 2k+1 / 2k+2)
    slot_of = np.asarray(tree.slot_of)
    quarter = tree.n_leaves // 4
    rows = np.flatnonzero(slot_of < quarter)
    assert rows.size > 0
    upd = vdt.delete_points(rows)
    new = upd.vdt

    assert float(np.asarray(new.tree.W)[3]) == 0.0
    assert np.all(np.asarray(new.tree.S1)[3] == 0.0)
    assert float(np.asarray(new.tree.S2)[3]) == 0.0
    # blocks with an emptied side are provably massless -> deactivated
    a, b, act = new.bp.a[:new.bp.n], new.bp.b[:new.bp.n], new.bp.active[:new.bp.n]
    w = np.asarray(new.tree.W)
    assert not np.any(act & ((w[a] == 0) | (w[b] == 0)))
    assert new.bp.n_active < vdt.bp.n_active
    assert_matches_recompute(new)

    # ...and inserting into the freed region reactivates coverage
    x_back = make_x(np.random.RandomState(8), rows.size, "sqeuclidean")
    upd2 = new.insert_points(x_back)
    assert upd2.vdt.bp.n_active > new.bp.n_active
    assert_matches_recompute(upd2.vdt)


def test_delete_to_single_point_then_refill():
    """A singleton model stays serveable; refilling from it stays exact."""
    rng = np.random.RandomState(13)
    x = make_x(rng, 9, "sqeuclidean")
    vdt = VariationalDualTree.fit(x, max_blocks=40, capacity=16)
    upd = vdt.delete_points(np.arange(8))
    one = upd.vdt
    assert one.tree.n_points == 1
    lp = np.asarray(one.label_propagate(np.ones((1, 2), np.float32),
                                        alpha=0.1, n_iters=4))
    assert np.all(np.isfinite(lp))

    upd2 = one.insert_points(make_x(rng, 10, "sqeuclidean"))
    assert upd2.vdt.tree.n_points == 11
    assert_matches_recompute(upd2.vdt)


def test_delete_all_rejected():
    rng = np.random.RandomState(17)
    vdt = VariationalDualTree.fit(make_x(rng, 6, "sqeuclidean"), max_blocks=20)
    with pytest.raises(ValueError, match="at least one"):
        vdt.delete_points(np.arange(6))


def test_capacity_error_names_remedy():
    rng = np.random.RandomState(19)
    vdt = VariationalDualTree.fit(make_x(rng, 8, "sqeuclidean"), max_blocks=20)
    free = vdt.tree.n_leaves - 8
    with pytest.raises(CapacityError, match="capacity"):
        vdt.insert_points(make_x(rng, free + 1, "sqeuclidean"))
    # deleting frees exactly that much headroom again
    upd = vdt.delete_points([0, 1])
    upd.vdt.insert_points(make_x(rng, free + 2, "sqeuclidean"))


def test_validation_errors():
    rng = np.random.RandomState(21)
    vdt = VariationalDualTree.fit(make_x(rng, 8, "sqeuclidean"),
                                  max_blocks=20, capacity=16)
    with pytest.raises(ValueError, match="points"):
        vdt.insert_points(np.zeros((2, DIM + 1), np.float32))
    with pytest.raises(ValueError, match="positive"):
        vdt.insert_points(make_x(rng, 2, "sqeuclidean"), weights=[1.0, -1.0])
    with pytest.raises(ValueError, match="row ids"):
        vdt.delete_points([0, 99])
    with pytest.raises(ValueError, match="empty"):
        vdt.delete_points([])
    # positive-domain divergence rejects out-of-domain inserts up front
    kl = VariationalDualTree.fit(make_x(rng, 8, "kl"), max_blocks=20,
                                 capacity=16, divergence="kl")
    with pytest.raises(ValueError):
        kl.insert_points(np.full((1, DIM), -1.0, np.float32))


def test_refine_spends_budget_on_stale_blocks_first():
    """Post-mutation refinement prioritizes the patched region."""
    rng = np.random.RandomState(29)
    x = make_x(rng, 40, "sqeuclidean")
    vdt = VariationalDualTree.fit(x, max_blocks=90, capacity=64)
    upd = vdt.insert_points(make_x(rng, 6, "sqeuclidean", scale=3.0))
    new = upd.vdt
    assert upd.stale_blocks > 0
    before_blocks, before_bound = new.n_blocks, new.bound
    new.refine(max_blocks=before_blocks + 8)
    assert new.n_blocks > before_blocks
    assert np.isfinite(new.bound) and new.bound >= before_bound - 1e-3
    # refinement regrew the partition: mirrors were dropped, and the next
    # mutation transparently rebuilds them
    assert_matches_recompute(new.delete_points([0]).vdt)


def test_insert_weights_carried():
    rng = np.random.RandomState(37)
    vdt = VariationalDualTree.fit(make_x(rng, 12, "sqeuclidean"),
                                  max_blocks=40, capacity=32)
    x_new = make_x(rng, 3, "sqeuclidean")
    upd = vdt.insert_points(x_new, weights=[2.0, 0.5, 3.0])
    w_leaf = np.asarray(upd.vdt.tree.w_leaf)
    slot_of = np.asarray(upd.vdt.tree.slot_of)
    np.testing.assert_allclose(w_leaf[slot_of[upd.rows]], [2.0, 0.5, 3.0])
    assert_matches_recompute(upd.vdt, unit_weights=False)


# ------------------------------------------------------------------- soak
@pytest.mark.slow
@pytest.mark.parametrize("div", DIVERGENCES)
def test_streaming_soak(div):
    """Long interleaved churn per divergence: drift must not accumulate."""
    rng = np.random.RandomState(41)
    x = make_x(rng, 96, div)
    vdt = VariationalDualTree.fit(x, max_blocks=320, capacity=192,
                                  divergence=div)
    x_mirror = np.asarray(vdt.x_rows).copy()
    ops = []
    for _ in range(30):
        ops.append(("ins", int(rng.randint(1, 9))))
        ops.append(("del", int(rng.randint(1, 9))))
    vdt, x_mirror = apply_ops(vdt, rng, div, ops, x_mirror)
    assert_matches_recompute(vdt, lp_atol=5e-3)
    jax.clear_caches()
