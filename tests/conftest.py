"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (in its own process)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def make_clusters(rng, n, d, n_classes=2, spread=1.0, sep=6.0):
    """Well-separated Gaussian clusters with labels."""
    labels = rng.randint(0, n_classes, size=n)
    centers = rng.randn(n_classes, d) * sep
    x = centers[labels] + rng.randn(n, d) * spread
    return x.astype(np.float32), labels
