"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (in its own process).

Fitting a VariationalDualTree is the dominant per-test cost (tree build +
sigma/q compiles), so fitted models that several tests can share are
session-scoped fixtures here — fit once, read everywhere."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def make_clusters(rng, n, d, n_classes=2, spread=1.0, sep=6.0):
    """Well-separated Gaussian clusters with labels."""
    labels = rng.randint(0, n_classes, size=n)
    centers = rng.randn(n_classes, d) * sep
    x = centers[labels] + rng.randn(n, d) * spread
    return x.astype(np.float32), labels


@pytest.fixture(scope="session")
def separated_clusters_vdt():
    """(x, labels, fitted vdt) on 2 well-separated clusters, n=128."""
    from repro.core.vdt import VariationalDualTree

    r = np.random.RandomState(7)
    x, labels = make_clusters(r, 128, 4, n_classes=2, sep=8.0)
    vdt = VariationalDualTree.fit(x, max_blocks=6 * 128)
    return x, labels, vdt


@pytest.fixture(scope="session")
def small_fitted_vdt():
    """(x, vdt) on n=33 gaussian data — shared by parity-style tests."""
    from repro.core.vdt import VariationalDualTree

    r = np.random.RandomState(3)
    x = r.randn(33, 4).astype(np.float32)
    vdt = VariationalDualTree.fit(x, max_blocks=4 * 33)
    return x, vdt
