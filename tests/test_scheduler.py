"""Scheduler v2: queue disciplines, adaptive linger, hybrid backend routing.

Everything here is deterministic: the queue-discipline property tests drive
``RequestQueue`` directly with hand-built entries and a fake clock, and the
engine integration tests use ``start=False`` + ``step``/``flush`` with the
same fake clock injected — no wall-clock sleeps, no thread races, so the
assertions hold on arbitrarily loaded CI runners.
"""
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core.label_prop import AUTO_EXACT_MAX_N, route_backend
from repro.serving import (DeadlineExceeded, PropagateEngine,
                           PropagateRequest)
from repro.serving._queue import DISCIPLINES, QueueEntry, RequestQueue


class FakeClock:
    """Deterministic time source for scheduler tests."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _entry(seq, *, t_submit=0.0, priority=0, t_deadline=None):
    return QueueEntry(seq=seq, request=f"req{seq}", future=Future(),
                      t_submit=t_submit, priority=priority,
                      t_deadline=t_deadline)


def _drain_seqs(q, max_items=1000):
    live, cancelled, expired = q.drain(max_items)
    return [e.seq for e in live]


# --------------------------------------------------------------- validation
def test_queue_rejects_bad_config():
    with pytest.raises(ValueError):
        RequestQueue(4, discipline="lifo")
    with pytest.raises(ValueError):
        RequestQueue(0)
    with pytest.raises(ValueError):
        RequestQueue(4, aging_s=0.0)
    assert set(DISCIPLINES) == {"fifo", "priority", "edf"}


# ----------------------------------------------------------- fifo discipline
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fifo_drain_is_submission_order(seed):
    """FIFO stays bit-identical to the original queue: any interleaving of
    puts and partial drains pops entries in exact submission order."""
    rng = np.random.RandomState(seed)
    q = RequestQueue(64, discipline="fifo")
    next_seq, popped = 0, []
    for _ in range(30):
        if rng.rand() < 0.6 or len(q) == 0:
            q.put(_entry(next_seq, t_submit=float(rng.rand())))
            next_seq += 1
        else:
            popped += _drain_seqs(q, max_items=int(rng.randint(1, 4)))
    popped += _drain_seqs(q)
    assert popped == list(range(next_seq))


def test_fifo_drain_filters_cancelled():
    q = RequestQueue(8)
    entries = [_entry(i) for i in range(5)]
    for e in entries:
        q.put(e)
    entries[1].future.cancel()
    entries[3].future.cancel()
    live, cancelled, expired = q.drain(10)
    assert [e.seq for e in live] == [0, 2, 4]
    assert [e.seq for e in cancelled] == [1, 3]
    assert expired == []
    assert len(q) == 0


# ------------------------------------------------------- priority discipline
def test_priority_ordering_respected():
    """Same submit instant: strictly highest priority first, FIFO ties."""
    q = RequestQueue(16, discipline="priority")
    for seq, pri in enumerate([0, 2, 1, 2, 0, 1]):
        q.put(_entry(seq, t_submit=0.0, priority=pri))
    # priority 2 entries (seq 1, 3), then 1s (2, 5), then 0s (0, 4);
    # equal-priority entries keep submission order
    assert _drain_seqs(q) == [1, 3, 2, 5, 0, 4]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_priority_equal_priorities_degrade_to_fifo(seed):
    rng = np.random.RandomState(seed)
    q = RequestQueue(64, discipline="priority", aging_s=0.5)
    n, t = 20, 0.0
    for seq in range(n):
        t += float(rng.rand()) * 1e-3  # monotone arrival times
        q.put(_entry(seq, t_submit=t, priority=3))
    assert _drain_seqs(q) == list(range(n))


def test_priority_aging_bounds_starvation():
    """A default-priority entry outranks higher-priority traffic submitted
    more than aging_s * (priority gap) later — nobody waits forever."""
    aging = 0.5
    q = RequestQueue(16, discipline="priority", aging_s=aging)
    q.put(_entry(0, t_submit=0.0, priority=0))  # the would-starve entry
    # fresh high-priority traffic *within* the aging bound still wins ...
    q.put(_entry(1, t_submit=0.3 * aging, priority=1))
    # ... but high-priority traffic submitted past the bound loses to it
    q.put(_entry(2, t_submit=1.5 * aging, priority=1))
    # a bigger priority gap scales the bound linearly (3 levels -> 3*aging):
    # submitted just inside it wins, just past it loses
    q.put(_entry(3, t_submit=2.9 * aging, priority=3))
    q.put(_entry(4, t_submit=3.1 * aging, priority=3))
    # ranks: e1=0.7, e3=0.1, e0=0.0, e4=-0.1, e2=-0.5
    assert _drain_seqs(q) == [1, 3, 0, 4, 2]


def test_priority_aging_rank_algebra():
    """Pin the aging rule itself: entry A (priority pa, submitted ta) beats
    entry B (pb, tb) iff pa - ta/aging > pb - tb/aging, ties by seq."""
    aging = 0.25
    rng = np.random.RandomState(5)
    entries = [_entry(seq, t_submit=float(rng.rand() * 2), priority=int(p))
               for seq, p in enumerate(rng.randint(0, 4, size=12))]
    q = RequestQueue(32, discipline="priority", aging_s=aging)
    for e in entries:
        q.put(e)
    want = sorted(
        entries,
        key=lambda e: (-(e.priority - e.t_submit / aging), e.seq))
    assert _drain_seqs(q) == [e.seq for e in want]


# ------------------------------------------------------------ edf discipline
def test_edf_earliest_deadline_first_deadlineless_last():
    clock = FakeClock(0.0)
    q = RequestQueue(16, discipline="edf", clock=clock)
    q.put(_entry(0, t_deadline=5.0))
    q.put(_entry(1, t_deadline=1.0))
    q.put(_entry(2))  # no deadline: after every deadlined entry
    q.put(_entry(3, t_deadline=3.0))
    q.put(_entry(4))  # ... and FIFO among themselves
    assert q.next_deadline() == 1.0
    assert _drain_seqs(q) == [1, 3, 0, 2, 4]
    assert q.next_deadline() is None


def test_edf_expired_entries_fast_fail():
    clock = FakeClock(0.0)
    q = RequestQueue(16, discipline="edf", clock=clock)
    q.put(_entry(0, t_deadline=0.1))
    q.put(_entry(1, t_deadline=10.0))
    q.put(_entry(2))
    clock.advance(1.0)  # entry 0 is now past its deadline
    live, cancelled, expired = q.drain(10)
    assert [e.seq for e in live] == [1, 2]
    assert [e.seq for e in expired] == [0]
    assert cancelled == []
    # expired entries free capacity without counting against max_items
    assert len(q) == 0


def test_non_edf_disciplines_never_expire():
    clock = FakeClock(0.0)
    for disc in ("fifo", "priority"):
        q = RequestQueue(16, discipline=disc, clock=clock)
        q.put(_entry(0, t_deadline=0.1))
        clock.t = 99.0
        live, _, expired = q.drain(10)
        assert [e.seq for e in live] == [0] and expired == []
        clock.t = 0.0


# ------------------------------------------------------------ backend routing
def test_route_backend_resolution():
    assert route_backend(None, "vdt") == "vdt"
    assert route_backend(None, "exact") == "exact"
    assert route_backend("vdt", "exact") == "vdt"
    assert route_backend("exact", "vdt") == "exact"
    assert route_backend("auto", "vdt", n=AUTO_EXACT_MAX_N) == "exact"
    assert route_backend("auto", "vdt", n=AUTO_EXACT_MAX_N + 1) == "vdt"
    assert route_backend("auto", "vdt", n=64, auto_exact_max_n=32) == "vdt"
    with pytest.raises(ValueError):
        route_backend("dense", "vdt")
    with pytest.raises(ValueError):
        route_backend("auto", "vdt")  # needs n


def test_engine_resolves_default_backend_at_construction(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, start=False, backend="auto")
    assert eng.backend == "exact"  # n=33 <= AUTO_EXACT_MAX_N
    assert eng.dispatch_key.startswith("exact:")
    with pytest.raises(ValueError):
        PropagateEngine(vdt, start=False, backend="dense")
    with pytest.raises(ValueError):
        PropagateEngine(vdt, start=False, policy="lifo")


# ------------------------------------------------- engine: hybrid dispatch
def test_engine_per_request_backend_routing(small_fitted_vdt):
    """One engine, mixed vdt/exact traffic: each answer matches its own
    backend's single-request reference, and the group-by key fragments by
    backend but never by alpha/width within a backend."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(21)
    mk = lambda c: (rng.rand(x.shape[0], c) > 0.8).astype(np.float32)  # noqa: E731
    reqs = [
        PropagateRequest(mk(2), alpha=0.05, n_iters=6),                  # default vdt
        PropagateRequest(mk(3), alpha=0.2, n_iters=6, backend="vdt"),
        PropagateRequest(mk(1), alpha=0.05, n_iters=6, backend="exact"),  # validation
        PropagateRequest(mk(2), alpha=0.1, n_iters=6, backend="auto"),   # -> exact (n=33)
    ]
    eng = PropagateEngine(vdt, start=False, max_batch=8)
    futs = [eng.submit(q) for q in reqs]
    eng.flush()
    m = eng.metrics()
    # 2 dispatch groups: {vdt, vdt} and {exact, auto->exact}
    assert m.dispatches == 2 and m.completed == 4
    backends = ["vdt", "vdt", "exact", "exact"]
    for fut, req, be in zip(futs, reqs, backends):
        want = vdt.label_propagate(req.y0, alpha=req.alpha,
                                   n_iters=req.n_iters, backend=be)
        np.testing.assert_allclose(np.asarray(fut.result(timeout=0)),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)


def test_engine_rejects_bad_request_backend(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, start=False)
    with pytest.raises(ValueError):
        eng.submit(PropagateRequest(np.zeros((x.shape[0], 2), np.float32),
                                    backend="dense"))
    with pytest.raises(ValueError):
        eng.submit(PropagateRequest(np.zeros((x.shape[0], 2), np.float32),
                                    deadline_ms=0.0))
    assert eng.metrics().submitted == 0


# ------------------------------------------------- engine: priority policy
def test_engine_priority_policy_serves_urgent_first(small_fitted_vdt):
    """With a backlog wider than max_batch, the priority engine spends its
    first dispatch slots on the highest-priority requests."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(22)
    y0 = (rng.rand(x.shape[0], 2) > 0.8).astype(np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="priority", max_batch=2,
                          clock=clock)
    futs = {}
    for i, pri in enumerate([0, 0, 0, 5, 0, 5]):
        futs[i] = eng.submit(PropagateRequest(y0, n_iters=4, priority=pri))
    eng.step()  # one microbatch of 2: must be the two priority-5 requests
    assert futs[3].done() and futs[5].done()
    assert not any(futs[i].done() for i in (0, 1, 2, 4))
    eng.flush()
    assert all(f.done() for f in futs.values())
    assert eng.metrics().completed == 6


def test_engine_priority_aging_prevents_starvation(small_fitted_vdt):
    """An old low-priority request eventually beats fresh high-priority
    traffic: the fake clock ages it past aging_ms * priority gap."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(23)
    y0 = (rng.rand(x.shape[0], 2) > 0.8).astype(np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="priority", max_batch=1,
                          aging_ms=100.0, clock=clock)
    old_low = eng.submit(PropagateRequest(y0, n_iters=4, priority=0))
    clock.advance(0.35)  # 350ms > aging_ms * (3 - 0)? no: bound is 300ms
    fresh_high = eng.submit(PropagateRequest(y0, n_iters=4, priority=3))
    eng.step()  # the aged default-priority request wins the single slot
    assert old_low.done() and not fresh_high.done()
    eng.flush()
    assert fresh_high.done()


# ------------------------------------------------------ engine: edf policy
def test_engine_edf_orders_by_deadline_and_fast_fails(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(24)
    y0 = (rng.rand(x.shape[0], 2) > 0.8).astype(np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="edf", max_batch=1,
                          clock=clock)
    tight = eng.submit(PropagateRequest(y0, n_iters=4, deadline_ms=50.0))
    loose = eng.submit(PropagateRequest(y0, n_iters=4, deadline_ms=5000.0))
    none = eng.submit(PropagateRequest(y0, n_iters=4))
    eng.step()  # tightest deadline wins the single slot
    assert tight.done() and not loose.done() and not none.done()

    # expire the loose one while queued: pinned exception, no dispatch spent
    clock.advance(10.0)
    eng.flush()
    with pytest.raises(DeadlineExceeded):
        loose.result(timeout=0)
    assert none.result(timeout=0) is not None
    m = eng.metrics()
    assert m.expired == 1 and m.completed == 2 and m.failed == 0


def test_engine_counts_late_completions_without_fast_fail(small_fitted_vdt):
    """fifo/priority policies still SERVE past-deadline requests but flag
    them as deadline_missed — only edf fast-fails."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(25)
    y0 = (rng.rand(x.shape[0], 2) > 0.8).astype(np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="fifo", clock=clock)
    fut = eng.submit(PropagateRequest(y0, n_iters=4, deadline_ms=10.0))
    clock.advance(1.0)  # way past the 10ms deadline
    eng.flush()
    assert fut.result(timeout=0) is not None  # still answered
    m = eng.metrics()
    assert m.completed == 1 and m.deadline_missed == 1 and m.expired == 0


# -------------------------------------------------- adaptive linger window
def test_adaptive_linger_tracks_arrival_rate(small_fitted_vdt):
    """The EWMA gap estimate drives the window: fast arrivals shrink it,
    and it never exceeds max_wait_ms."""
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, max_batch=4, max_wait_ms=50.0,
                          clock=clock)
    # no rate estimate yet: fall back to the cap
    assert eng._linger_window_s() == pytest.approx(0.050)

    for _ in range(8):  # steady 2ms inter-arrival gaps
        clock.advance(0.002)
        eng.submit(PropagateRequest(y0, n_iters=2))
    assert eng._ewma_gap_s == pytest.approx(0.002, rel=1e-6)
    # queue holds 8 >= max_batch=4 -> nothing missing -> no linger at all
    assert eng._linger_window_s() == 0.0
    eng.flush()

    # now one lone arrival: 3 slots missing at ~2ms/arrival -> ~6ms window,
    # far below the 50ms cap
    clock.advance(0.002)
    eng.submit(PropagateRequest(y0, n_iters=2))
    assert eng._linger_window_s() == pytest.approx(3 * eng._ewma_gap_s)
    eng.flush()

    # slow traffic: gaps bigger than the cap clamp to max_wait_ms
    for _ in range(8):
        clock.advance(10.0)
        eng.submit(PropagateRequest(y0, n_iters=2))
        eng.flush()
    clock.advance(10.0)
    eng.submit(PropagateRequest(y0, n_iters=2))
    assert eng._linger_window_s() == pytest.approx(0.050)
    eng.flush()
    # the chosen window is observable for operators
    assert eng.metrics().linger_window_ms == pytest.approx(50.0)


def test_adaptive_linger_capped_by_nearest_deadline(small_fitted_vdt):
    """Under edf, lingering never extends past the most urgent deadline."""
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="edf", max_batch=8,
                          max_wait_ms=100.0, clock=clock)
    eng.submit(PropagateRequest(y0, n_iters=2, deadline_ms=20.0))
    # cap (100ms) > deadline distance (20ms): the deadline wins
    assert eng._linger_window_s() == pytest.approx(0.020)
    clock.advance(0.015)
    assert eng._linger_window_s() == pytest.approx(0.005)
    eng.flush()


def test_linger_shrinks_for_deadline_arriving_mid_window(
        small_fitted_vdt, monkeypatch):
    """A tight-deadline request landing DURING the linger must shrink the
    window: the loop re-checks next_deadline() every iteration, so batching
    can never itself expire the most urgent request."""
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="edf", max_batch=64,
                          max_wait_ms=1000.0, clock=clock)
    eng.submit(PropagateRequest(y0, n_iters=2))  # deadline-less opener
    calls = []

    def wait_and_arrive(n, timeout=None):
        # stand-in for the real condition wait: every "wait" sees 5ms pass
        # and one more arrival, so the quiesce early-exit never fires and
        # the loop runs until its deadline bound stops it
        calls.append(timeout)
        clock.advance(0.005)
        if len(calls) == 1:  # mid-linger: a 10ms-deadline request lands
            eng.submit(PropagateRequest(y0, n_iters=2, deadline_ms=10.0))
        else:
            eng.submit(PropagateRequest(y0, n_iters=2))
        return False

    monkeypatch.setattr(eng._queue, "wait_atleast", wait_and_arrive)
    eng._linger()
    # the tight deadline (15ms absolute) must end the linger within a few
    # 5ms waits; without the per-iteration re-check the loop would keep
    # waiting toward the 1000ms cap (~60+ calls before max_batch fills)
    assert len(calls) <= 4
    eng.flush()


def test_fixed_linger_opt_out(small_fitted_vdt):
    """adaptive_linger=False restores the fixed max_wait_ms window."""
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    clock = FakeClock()
    eng = PropagateEngine(vdt, start=False, max_wait_ms=30.0,
                          adaptive_linger=False, clock=clock)
    for _ in range(4):
        clock.advance(0.001)
        eng.submit(PropagateRequest(y0, n_iters=2))
    assert eng._linger_window_s() == pytest.approx(0.030)
    eng.flush()
