"""Exact / kNN baselines and end-to-end Label Propagation behaviour."""
import sys
from pathlib import Path

import numpy as np

import jax.numpy as jnp

sys.path.insert(0, str(Path(__file__).parent))
from conftest import make_clusters

from repro.core.baselines import (
    build_knn_graph,
    exact_transition_matrix,
    knn_matvec,
    streaming_exact_matvec,
)
from repro.core.label_prop import ccr, label_propagate, one_hot_labels
from repro.core.vdt import VariationalDualTree


def test_exact_p_row_stochastic(rng):
    x = rng.randn(30, 4).astype(np.float32)
    p = np.asarray(exact_transition_matrix(jnp.asarray(x), jnp.asarray(1.0)))
    np.testing.assert_allclose(p.sum(1), np.ones(30), rtol=1e-5)
    assert np.all(np.diagonal(p) == 0)
    assert np.all(p >= 0)


def test_streaming_matvec_matches_dense(rng):
    n, d, c = 67, 5, 3
    x = rng.randn(n, d).astype(np.float32)
    y = rng.randn(n, c).astype(np.float32)
    sigma = jnp.asarray(0.8)
    p = np.asarray(exact_transition_matrix(jnp.asarray(x), sigma))
    out = np.asarray(streaming_exact_matvec(jnp.asarray(x), jnp.asarray(y),
                                            sigma, block=16))
    np.testing.assert_allclose(out, p @ y, rtol=1e-4, atol=1e-5)


def test_knn_graph_correct_neighbours(rng):
    n, k = 40, 5
    x = rng.randn(n, 3).astype(np.float32)
    g = build_knn_graph(jnp.asarray(x), k, jnp.asarray(1.0), block=16)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    for i in range(n):
        want = set(np.argsort(d2[i])[:k].tolist())
        got = set(np.asarray(g.indices[i]).tolist())
        # ties can permute equal-distance neighbours; compare distances
        dw = sorted(d2[i][list(want)])
        dg = sorted(d2[i][list(got)])
        np.testing.assert_allclose(dg, dw, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g.weights).sum(1), np.ones(n), rtol=1e-5)


def test_knn_matvec_matches_dense_sparse(rng):
    n, k, c = 25, 4, 2
    x = rng.randn(n, 3).astype(np.float32)
    g = build_knn_graph(jnp.asarray(x), k, jnp.asarray(1.0), block=8)
    y = rng.randn(n, c).astype(np.float32)
    dense = np.zeros((n, n))
    idx = np.asarray(g.indices); w = np.asarray(g.weights)
    for i in range(n):
        dense[i, idx[i]] = w[i]
    out = np.asarray(knn_matvec(g, jnp.asarray(y)))
    np.testing.assert_allclose(out, dense @ y, rtol=1e-4, atol=1e-6)


def _lp_ccr(matvec, labels, labeled_mask, n_classes, alpha=0.05, iters=150):
    y0 = one_hot_labels(labels, labeled_mask, n_classes)
    yf = label_propagate(matvec, y0, alpha=alpha, n_iters=iters)
    return ccr(yf, labels, ~labeled_mask)


def test_label_propagation_separated_clusters(rng, separated_clusters_vdt):
    """All three backends must classify well-separated clusters near-perfectly
    with 10% labels — the paper's qualitative Figure 2C claim."""
    x, labels, vdt = separated_clusters_vdt
    n = x.shape[0]
    labeled = np.zeros(n, bool)
    labeled[rng.choice(n, n // 10, replace=False)] = True

    # VDT (fitted once in the session-scoped fixture)
    acc_vdt = _lp_ccr(vdt.matvec, labels, labeled, 2)

    # exact
    p = exact_transition_matrix(jnp.asarray(x), jnp.asarray(vdt.sigma))
    acc_exact = _lp_ccr(lambda y: p @ y, labels, labeled, 2)

    # kNN
    g = build_knn_graph(jnp.asarray(x), 8, jnp.asarray(vdt.sigma))
    acc_knn = _lp_ccr(lambda y: knn_matvec(g, y), labels, labeled, 2)

    assert acc_exact > 0.95, acc_exact
    assert acc_vdt > 0.9, acc_vdt
    assert acc_knn > 0.9, acc_knn


def test_vdt_close_to_exact_on_moderate_data():
    """VDT CCR should be within a few points of exact CCR (paper Fig. 2C).

    Dedicated RandomState: the shared session `rng` stream shifts whenever
    earlier tests change their draw counts, and this margin is seed-tight."""
    rng = np.random.RandomState(1)
    n = 96
    x, labels = make_clusters(rng, n, 6, n_classes=3, sep=5.0, spread=1.2)
    labeled = np.zeros(n, bool)
    labeled[rng.choice(n, max(6, n // 10), replace=False)] = True
    vdt = VariationalDualTree.fit(x, max_blocks=8 * n)
    p = exact_transition_matrix(jnp.asarray(x), jnp.asarray(vdt.sigma))
    acc_vdt = _lp_ccr(vdt.matvec, labels, labeled, 3)
    acc_exact = _lp_ccr(lambda y: p @ y, labels, labeled, 3)
    assert acc_vdt >= acc_exact - 0.15, (acc_vdt, acc_exact)


def test_lp_fixed_point_property(rng):
    """LP converges toward the fixed point Y* = (1-a)(I - a Q)^-1 Y0."""
    n = 32
    x, labels = make_clusters(rng, n, 3, sep=6.0)
    labeled = np.zeros(n, bool); labeled[:6] = True
    vdt = VariationalDualTree.fit(x)
    y0 = np.asarray(one_hot_labels(labels, labeled, 2))
    q = vdt.dense_q()
    alpha = 0.1
    y_star = (1 - alpha) * np.linalg.solve(np.eye(n) - alpha * q, y0)
    yf = np.asarray(vdt.label_propagate(y0, alpha=alpha, n_iters=300))
    np.testing.assert_allclose(yf, y_star, rtol=1e-3, atol=1e-4)
