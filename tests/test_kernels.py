"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.divergence import mahalanobis
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.fused_lp import (fused_lp_matvec, fused_lp_matvec_dense_ref,
                                    fused_lp_scan_batched,
                                    fused_lp_scan_batched_ref,
                                    fused_lp_scan_folded,
                                    fused_lp_step_batched,
                                    fused_lp_step_batched_ref,
                                    fused_lp_step_folded)
from repro.kernels.pairwise import pairwise_sq_dists, pairwise_sq_dists_ref


# --------------------------------------------------------------- pairwise
@pytest.mark.parametrize("m,n,d", [
    (8, 8, 4), (100, 64, 7),
    # big ragged shapes are interpret-mode-slow on CPU -> slow tier
    pytest.param(257, 129, 16, marks=pytest.mark.slow),
    pytest.param(64, 300, 33, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_matches_ref(rng, m, n, d, dtype):
    x = jnp.asarray(rng.randn(m, d), dtype)
    y = jnp.asarray(rng.randn(n, d), dtype)
    got = pairwise_sq_dists(x, y, block_m=64, block_n=64)
    want = pairwise_sq_dists_ref(x, y)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_pairwise_zero_diag_when_same(rng):
    x = jnp.asarray(rng.randn(40, 5), jnp.float32)
    d2 = pairwise_sq_dists(x, x, block_m=32, block_n=32)
    assert np.allclose(np.diagonal(np.asarray(d2)), 0.0, atol=1e-3)


# ---------------------------------------------------------------- fused_lp
@pytest.mark.parametrize("n,d,c,sigma", [
    (32, 4, 2, 1.0), (100, 8, 3, 0.5), (130, 5, 1, 2.0), (64, 16, 7, 1.0),
])
def test_fused_lp_matches_dense(rng, n, d, c, sigma):
    x = jnp.asarray(rng.randn(n, d), jnp.float32)
    y = jnp.asarray(rng.randn(n, c), jnp.float32)
    got = fused_lp_matvec(x, y, sigma, block_m=32, block_n=32)
    want = fused_lp_matvec_dense_ref(x, y, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_fused_lp_extreme_sigma(rng):
    """Online softmax must stay stable for tiny bandwidths (huge logits)."""
    x = jnp.asarray(rng.randn(48, 3), jnp.float32)
    y = jnp.asarray(rng.randn(48, 2), jnp.float32)
    for sigma in (0.05, 10.0):
        got = np.asarray(fused_lp_matvec(x, y, sigma, block_m=16, block_n=16))
        want = np.asarray(fused_lp_matvec_dense_ref(x, y, sigma))
        assert np.isfinite(got).all()
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_fused_lp_row_stochastic_action(rng):
    """P is row-stochastic: P @ 1 == 1 exactly through the kernel."""
    x = jnp.asarray(rng.randn(70, 6), jnp.float32)
    ones = jnp.ones((70, 1), jnp.float32)
    got = np.asarray(fused_lp_matvec(x, ones, 1.0, block_m=32, block_n=32))
    np.testing.assert_allclose(got, 1.0, rtol=1e-5)


# ----------------------------------------------- distance-reusing folded LP
@pytest.mark.parametrize("n,k,sigma", [(40, 3, 1.0), (65, 8, 0.5), (33, 1, 2.0)])
def test_fused_lp_step_folded_matches_dense(rng, n, k, sigma):
    """The folded step (distances computed once for all K columns) equals the
    dense eq.-15 update, scalar alpha."""
    x = jnp.asarray(rng.randn(n, 5), jnp.float32)
    y = jnp.asarray(rng.randn(n, k), jnp.float32)
    y0 = jnp.asarray(rng.randn(n, k), jnp.float32)
    got = fused_lp_step_folded(x, y, y0, sigma, 0.1, block_m=16, block_n=16)
    want = fused_lp_step_batched_ref(x, y[None], y0[None], sigma, 0.1)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


def test_fused_lp_step_folded_per_column_alpha(rng):
    """A traced (K,) alpha applies per column — the layout per-request alphas
    ride through after the batch folds into channels."""
    n, k = 48, 4
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    y = jnp.asarray(rng.randn(n, k), jnp.float32)
    y0 = jnp.asarray(rng.randn(n, k), jnp.float32)
    al = jnp.asarray([0.0, 0.05, 0.5, 1.0], jnp.float32)
    got = np.asarray(fused_lp_step_folded(x, y, y0, 1.0, al,
                                          block_m=16, block_n=16))
    py = np.asarray(fused_lp_matvec_dense_ref(x, y, 1.0))
    want = np.asarray(al)[None, :] * py + (1.0 - np.asarray(al))[None, :] * np.asarray(y0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("n_iters", [1, 5])
def test_fused_lp_scan_folded_matches_iterated_dense(rng, n_iters):
    """The multi-iteration scan (Y resident in the folded padded layout)
    equals n_iters explicit dense eq.-15 iterations within 1e-5."""
    n, k = 37, 3  # non-power-of-two: padded rows must never leak back in
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    y0 = jnp.asarray(rng.randn(n, k), jnp.float32)
    got = fused_lp_scan_folded(x, y0, 1.0, jnp.float32(0.1), n_iters,
                               block_m=16, block_n=16)
    want = fused_lp_scan_batched_ref(x, y0[None], 1.0, 0.1, n_iters)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------- divergence × B × C × odd-N grid
def _divergence_param(name: str, d: int):
    """Grid entries: registry names plus a non-trivially-scaled Mahalanobis."""
    if name == "mahalanobis-scaled":
        return mahalanobis(np.linspace(0.5, 2.0, d))
    return name


DIV_GRID = ["sqeuclidean", "kl", "itakura_saito", "mahalanobis-scaled"]


@pytest.mark.parametrize("divergence", DIV_GRID)
@pytest.mark.parametrize("b,c,n", [
    (2, 2, 33), (3, 1, 41),
    # big odd shapes are interpret-mode-slow on CPU -> slow tier
    pytest.param(4, 3, 129, marks=pytest.mark.slow),
    pytest.param(8, 2, 257, marks=pytest.mark.slow),
])
def test_divergence_kernel_parity_grid(rng, divergence, b, c, n):
    """Folded-reuse kernel == legacy per-batch kernel == dense oracle, for
    every divergence: one step and a short scan, odd N (padding must stay
    invisible — for KL/IS the pad value is what keeps tiles finite)."""
    d = 5
    div = _divergence_param(divergence, d)
    x = jnp.asarray(rng.rand(n, d) + 0.1, jnp.float32)  # in-domain for all
    y = jnp.asarray(rng.rand(b, n, c), jnp.float32)
    y0 = jnp.asarray(rng.rand(b, n, c), jnp.float32)
    alpha = 0.1

    want = np.asarray(fused_lp_step_batched_ref(x, y, y0, 1.0, alpha,
                                                divergence=div))
    got_reuse = np.asarray(fused_lp_step_batched(
        x, y, y0, 1.0, alpha, block_m=16, block_n=16, reuse=True,
        divergence=div))
    got_legacy = np.asarray(fused_lp_step_batched(
        x, y, y0, 1.0, alpha, block_m=16, block_n=16, reuse=False,
        divergence=div))
    np.testing.assert_allclose(got_reuse, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_legacy, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_reuse, got_legacy, rtol=1e-4, atol=1e-5)

    got_scan = np.asarray(fused_lp_scan_batched(
        x, y0, 1.0, alpha, 3, block_m=16, block_n=16, divergence=div))
    want_scan = np.asarray(fused_lp_scan_batched_ref(x, y0, 1.0, alpha, 3,
                                                     divergence=div))
    np.testing.assert_allclose(got_scan, want_scan, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("divergence", ["kl", "itakura_saito"])
def test_divergence_row_stochastic_action(rng, divergence):
    """The generalized transition matrix is still row-stochastic through the
    kernel: P @ 1 == 1 for Bregman similarities too."""
    n = 53
    x = jnp.asarray(rng.rand(n, 4) + 0.1, jnp.float32)
    ones = jnp.ones((n, 1), jnp.float32)
    got = np.asarray(fused_lp_matvec(x, ones, 1.0, block_m=16, block_n=16,
                                     divergence=divergence))
    np.testing.assert_allclose(got, 1.0, rtol=1e-5)


def test_divergence_per_request_alpha_reuse(rng):
    """Per-request (B,) alphas ride the folded KL kernel exactly."""
    b, n, c = 3, 29, 2
    x = jnp.asarray(rng.rand(n, 4) + 0.1, jnp.float32)
    y0 = jnp.asarray(rng.rand(b, n, c), jnp.float32)
    al = jnp.asarray([0.0, 0.2, 1.0], jnp.float32)
    got = np.asarray(fused_lp_scan_batched(x, y0, 1.0, al, 2,
                                           block_m=16, block_n=16,
                                           divergence="kl"))
    want = np.asarray(fused_lp_scan_batched_ref(x, y0, 1.0, al, 2,
                                                divergence="kl"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 64, 16), (2, 4, 2, 96, 32), (1, 8, 1, 128, 16), (2, 3, 1, 65, 8),
])
def test_flash_attention_causal(rng, b, hq, hkv, s, d):
    q = jnp.asarray(rng.randn(b, hq, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, hkv, s, d), jnp.float32)
    got = flash_attention(q, k, v, block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(rng, window):
    b, h, s, d = 1, 2, 128, 16
    q = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, h, s, d), jnp.float32)
    got = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(rng, dtype):
    b, h, s, d = 1, 2, 64, 32
    q = jnp.asarray(rng.randn(b, h, s, d), dtype)
    k = jnp.asarray(rng.randn(b, h, s, d), dtype)
    v = jnp.asarray(rng.randn(b, h, s, d), dtype)
    got = np.asarray(flash_attention(q, k, v, block_q=32, block_k=32),
                     np.float32)
    want = np.asarray(flash_attention_ref(q, k, v), np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_flash_attention_matches_model_attention(rng):
    """The kernel agrees with the model's attn_apply (no rope, causal)."""
    from repro.models.attention import attn_apply
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      head_dim=16)
    b, s = 2, 64
    x = jnp.asarray(rng.randn(b, s, 64), jnp.float32)
    params = {
        "w_q": jnp.asarray(rng.randn(64, 64), jnp.float32) * 0.1,
        "w_k": jnp.asarray(rng.randn(64, 32), jnp.float32) * 0.1,
        "w_v": jnp.asarray(rng.randn(64, 32), jnp.float32) * 0.1,
        "w_o": jnp.eye(64, dtype=jnp.float32),
    }
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ref_out = attn_apply(params, x, cfg, pos, use_rope=False)

    q = (x @ params["w_q"]).reshape(b, s, 4, 16).transpose(0, 2, 1, 3)
    k = (x @ params["w_k"]).reshape(b, s, 2, 16).transpose(0, 2, 1, 3)
    v = (x @ params["w_v"]).reshape(b, s, 2, 16).transpose(0, 2, 1, 3)
    o = flash_attention(q, k, v, block_q=32, block_k=32)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, 64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref_out),
                               rtol=2e-3, atol=2e-3)
