"""EngineFleet: tenant routing, DRR fairness, isolation, parity, metrics."""
import numpy as np
import pytest

from repro.serving import (DeadlineExceeded, EngineFleet, PropagateEngine,
                           PropagateRequest)

ITERS = 4  # plenty for parity, cheap enough for tier-1


class FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(rng, n, c=2, tenant=None, **kw):
    y0 = (rng.rand(n, c) > 0.8).astype(np.float32)
    return PropagateRequest(y0, alpha=0.05, n_iters=ITERS, tenant=tenant, **kw)


# --------------------------------------------------------------- routing
def test_routing_by_tenant_and_errors(small_fitted_vdt, rng):
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("a", vdt)
    fleet.register("b", vdt)
    assert fleet.tenants() == ("a", "b")

    fa = fleet.submit(_req(rng, n, tenant="a"))
    fb = fleet.submit(_req(rng, n, tenant="b"))
    # multi-tenant fleet refuses to guess a route
    with pytest.raises(ValueError, match="request.tenant is required"):
        fleet.submit(_req(rng, n))
    with pytest.raises(ValueError, match="unknown tenant 'zz'"):
        fleet.submit(_req(rng, n, tenant="zz"))
    fleet.flush()
    assert fa.result(timeout=5).shape == (n, 2)
    assert fb.result(timeout=5).shape == (n, 2)
    fleet.shutdown()


def test_single_tenant_none_routes_to_sole_tenant(small_fitted_vdt, rng):
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    with EngineFleet(start=False, clock=FakeClock()) as fleet:
        fleet.register("only", vdt)
        fut = fleet.submit(_req(rng, n))  # tenant=None -> "only"
        fleet.flush()
        assert fut.result(timeout=5).shape == (n, 2)


def test_register_errors(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("a", vdt)
    with pytest.raises(ValueError, match="already registered"):
        fleet.register("a", vdt)
    with pytest.raises(ValueError, match="weight must be > 0"):
        fleet.register("b", vdt, weight=0.0)
    with pytest.raises(ValueError, match="fleet-managed"):
        fleet.register("b", vdt, start=True)
    with pytest.raises(ValueError, match="fleet-managed"):
        fleet.register("b", vdt, clock=FakeClock())
    fleet.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        fleet.register("c", vdt)
    with pytest.raises(RuntimeError, match="shut down"):
        fleet.submit(PropagateRequest(np.zeros((1, 1), np.float32)))


def test_quantum_must_be_positive():
    with pytest.raises(ValueError, match="quantum must be > 0"):
        EngineFleet(quantum=0.0, start=False)


# ----------------------------------------------------------- DRR fairness
def test_drr_weight_proportional_throughput(small_fitted_vdt, rng):
    """Sustained all-backlogged load splits 3:1 by weight, exactly.

    quantum*weight credit per round with max_batch=4 microbatches means
    the gold tenant dispatches 12 requests/round and bronze 4/round while
    both stay backlogged — lifetime shares converge to the weights and
    ``fair_share_err`` goes to ~0.
    """
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock(), quantum=4.0)
    fleet.register("gold", vdt, weight=3.0, max_batch=4, max_queue=64)
    fleet.register("bronze", vdt, weight=1.0, max_batch=4, max_queue=64)
    for _ in range(48):
        fleet.submit(_req(rng, n, tenant="gold"), block=False)
        fleet.submit(_req(rng, n, tenant="bronze"), block=False)

    # run rounds only while BOTH tenants stay backlogged: that is the
    # regime where DRR's share guarantee applies
    for _ in range(3):
        assert fleet.step_round() > 0
    m = fleet.metrics()
    assert m.rounds == 3
    assert m.served["gold"] == 36  # 3 rounds * quantum 4 * weight 3
    assert m.served["bronze"] == 12  # 3 rounds * quantum 4 * weight 1
    share = m.served["gold"] / (m.served["gold"] + m.served["bronze"])
    assert abs(share - 0.75) < 0.15 * 0.75
    assert m.fair_share_err < 0.15
    fleet.shutdown()  # serves the leftover backlog


def test_drr_starvation_bound(small_fitted_vdt, rng):
    """A tiny-weight tenant still dispatches: its deficit grows every
    backlogged round, so it is served within max_batch/(quantum*weight)
    rounds of joining — never starved outright by heavier tenants."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock(), quantum=1.0)
    fleet.register("heavy", vdt, weight=10.0, max_batch=4, max_queue=256)
    fleet.register("light", vdt, weight=0.25, max_batch=4, max_queue=256)
    for _ in range(200):
        fleet.submit(_req(rng, n, tenant="heavy"), block=False)
    light_fut = fleet.submit(_req(rng, n, tenant="light"), block=False)
    # quantum*weight = 0.25/round -> light's single request (cost 1, i.e.
    # one sub-max_batch dispatch) must go out by round ceil(1/0.25) = 4
    for _ in range(4):
        fleet.step_round()
    assert light_fut.done()
    assert light_fut.result().shape == (n, 2)
    fleet.shutdown(wait=False)


def test_idle_tenant_banks_no_credit(small_fitted_vdt, rng):
    """Classic DRR: an empty queue resets the deficit, so a tenant cannot
    idle through rounds and then burst past its weight share."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock(), quantum=2.0)
    fleet.register("a", vdt, weight=1.0, max_batch=2, max_queue=64)
    fleet.register("b", vdt, weight=1.0, max_batch=2, max_queue=64)
    for _ in range(8):
        fleet.submit(_req(rng, n, tenant="b"), block=False)
    for _ in range(10):  # "a" idles; its deficit must stay reset at 0
        fleet.step_round()
    assert fleet._tenants["a"].deficit == 0.0
    fleet.shutdown()


# --------------------------------------------------------------- isolation
def test_tenant_isolation_failures_never_cross(small_fitted_vdt, rng):
    """Nothing that happens to tenant A's entries — cancellation, EDF
    expiry — touches tenant B's futures, and vice versa."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    clock = FakeClock()
    fleet = EngineFleet(start=False, clock=clock)
    fleet.register("a", vdt, policy="edf")
    fleet.register("b", vdt)

    doomed = fleet.submit(_req(rng, n, tenant="a", deadline_ms=5.0))
    cancelled = fleet.submit(_req(rng, n, tenant="a", deadline_ms=1000.0))
    healthy = fleet.submit(_req(rng, n, tenant="b"))
    assert cancelled.cancel()
    clock.advance(0.05)  # expire `doomed` while queued
    fleet.step_round()
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=5)
    # B's future resolved normally despite A's round of failures
    assert healthy.result(timeout=5).shape == (n, 2)
    ma = fleet.metrics().tenants
    assert ma["a"].expired == 1
    assert ma["a"].cancelled == 1
    assert ma["a"].completed == 0
    assert ma["b"].completed == 1
    assert ma["b"].expired == 0
    fleet.shutdown()


def test_backpressure_is_per_tenant(small_fitted_vdt, rng):
    """One tenant hitting QueueFull must not consume another's capacity."""
    from repro.serving import QueueFull

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("tiny", vdt, max_queue=2)
    fleet.register("roomy", vdt, max_queue=64)
    fleet.submit(_req(rng, n, tenant="tiny"), block=False)
    fleet.submit(_req(rng, n, tenant="tiny"), block=False)
    with pytest.raises(QueueFull):
        fleet.submit(_req(rng, n, tenant="tiny"), block=False)
    # roomy is unaffected by tiny's backpressure
    fut = fleet.submit(_req(rng, n, tenant="roomy"), block=False)
    fleet.flush()
    assert fut.result(timeout=5).shape == (n, 2)
    fleet.shutdown()


# ------------------------------------------------------------------ parity
def test_single_tenant_fleet_bit_identical_to_bare_engine(
        small_fitted_vdt, rng):
    """Routing + DRR around one tenant adds NOTHING to the math: answers
    from a single-tenant fleet are bit-identical to a bare engine fed the
    same requests in the same order."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    reqs = []
    r = np.random.RandomState(11)
    for _ in range(17):  # mixed widths/alphas, incl. sub-bucket widths
        c = int(r.choice((1, 2, 3, 4, 6)))
        y0 = (r.rand(n, c) > 0.8).astype(np.float32)
        reqs.append(PropagateRequest(y0, alpha=float(r.choice((0.01, 0.2))),
                                     n_iters=ITERS))

    bare = PropagateEngine(vdt, start=False, clock=FakeClock(), max_batch=8)
    bare_futs = [bare.submit(q) for q in reqs]
    bare.flush()
    bare_out = [np.asarray(f.result(timeout=5)) for f in bare_futs]
    bare.shutdown()

    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("solo", vdt, max_batch=8)
    fleet_futs = [fleet.submit(q) for q in reqs]
    fleet.flush()
    fleet_out = [np.asarray(f.result(timeout=5)) for f in fleet_futs]
    fleet.shutdown()

    for a, b in zip(bare_out, fleet_out):
        assert a.shape == b.shape
        assert np.array_equal(a, b)  # bit-identical, not merely close


# ----------------------------------------------------------------- metrics
def test_metrics_snapshots_share_no_mutable_state(small_fitted_vdt, rng):
    """The satellite bugfix contract: fleet metrics are deep-copied and
    tenant-keyed — mutating a snapshot never corrupts the live scheduler,
    and two snapshots never alias each other."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("a", vdt, weight=2.0)
    fleet.register("b", vdt)
    f = fleet.submit(_req(rng, n, tenant="a"))
    fleet.step_round()
    f.result(timeout=5)

    snap1 = fleet.metrics()
    snap2 = fleet.metrics()
    # no aliasing between snapshots (per-tenant engine snapshots are
    # frozen dataclasses of scalars, so the mappings are the mutable part)
    assert snap1.served is not snap2.served
    assert snap1.weights is not snap2.weights
    assert snap1.tenants is not snap2.tenants
    # ...and mutating a snapshot cannot reach live state
    snap1.served["a"] = 10**6
    snap1.weights["a"] = 0.0
    del snap1.tenants["a"]
    assert fleet._tenants["a"].served == 1
    assert fleet._tenants["a"].weight == 2.0
    snap3 = fleet.metrics()
    assert snap3.served["a"] == 1
    assert snap3.weights["a"] == 2.0
    assert snap3.tenants["a"].completed == 1
    fleet.shutdown()


def test_fair_share_err_nan_until_meaningful(small_fitted_vdt, rng):
    x, vdt = small_fitted_vdt
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("a", vdt)
    assert np.isnan(fleet.metrics().fair_share_err)  # single tenant
    fleet.register("b", vdt)
    assert np.isnan(fleet.metrics().fair_share_err)  # nothing served yet
    fleet.shutdown()


# ----------------------------------------------------- per-tenant epochs
def test_publish_is_per_tenant(small_fitted_vdt, rng):
    """A streaming publish to one tenant must not move any other tenant's
    epoch, validation contract, or already-queued answers."""
    x, vdt0 = small_fitted_vdt
    n0 = x.shape[0]
    r = np.random.RandomState(51)
    upd = vdt0.delete_points([1, 4])
    vdt1 = upd.vdt.insert_points(
        r.randn(6, x.shape[1]).astype(np.float32)).vdt
    n1 = vdt1.tree.n_points
    assert n1 != n0

    # control: what b's queued request resolves to with no publish anywhere
    control = EngineFleet(start=False, clock=FakeClock())
    control.register("b", vdt0)
    req_b = _req(np.random.RandomState(61), n0, tenant="b")
    want_b = control.submit(req_b)
    control.flush()
    want_b = np.asarray(want_b.result(timeout=5))
    control.shutdown()

    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("a", vdt0)
    fleet.register("b", vdt0)
    fut_a = fleet.submit(_req(np.random.RandomState(60), n0, tenant="a"))
    fut_b = fleet.submit(_req(np.random.RandomState(61), n0, tenant="b"))

    eid = fleet.publish("a", vdt1, patched_points=upd.patched_points)
    assert eid == 1
    snap = fleet.metrics().tenants
    assert snap["a"].epoch == 1 and snap["a"].epochs_published == 1
    assert snap["a"].live_epochs == 2  # a's queued entry pins epoch 0
    assert snap["b"].epoch == 0 and snap["b"].epochs_published == 0

    # post-publish validation: a wants the new N, b still wants the old one
    with pytest.raises(ValueError):
        fleet.submit(_req(rng, n0, tenant="a"))
    fut_a2 = fleet.submit(_req(np.random.RandomState(62), n1, tenant="a"))
    with pytest.raises(ValueError):
        fleet.submit(_req(rng, n1, tenant="b"))

    fleet.flush()
    assert fut_a.result(timeout=5).shape == (n0, 2)  # old epoch, old shape
    assert fut_a2.result(timeout=5).shape == (n1, 2)
    # b's answer is bit-identical to the publish-free control fleet
    assert np.array_equal(np.asarray(fut_b.result(timeout=5)), want_b)

    snap = fleet.metrics().tenants
    assert snap["a"].live_epochs == 1 and snap["a"].epochs_retired == 1
    assert snap["b"].live_epochs == 1 and snap["b"].epochs_retired == 0
    fleet.shutdown()


def test_publish_routing_and_errors(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    fleet = EngineFleet(start=False, clock=FakeClock())
    fleet.register("only", vdt)
    assert fleet.publish(None, vdt) == 1  # sole tenant: None routes like submit
    fleet.register("other", vdt)
    with pytest.raises(ValueError, match="tenant"):
        fleet.publish(None, vdt)
    with pytest.raises(ValueError, match="unknown tenant"):
        fleet.publish("zz", vdt)
    fleet.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        fleet.publish("only", vdt)


# ---------------------------------------------------------------- threaded
def test_background_fleet_serves_end_to_end(small_fitted_vdt, rng):
    """start=True smoke test on the real clock: the fleet thread routes,
    schedules, and resolves without manual stepping."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    with EngineFleet() as fleet:
        fleet.register("a", vdt, weight=2.0)
        fleet.register("b", vdt)
        futs = [fleet.submit(_req(rng, n, tenant=t))
                for t in ("a", "b", "a", "b", "a")]
        outs = [f.result(timeout=30) for f in futs]
    assert all(o.shape == (n, 2) for o in outs)
    m = fleet.metrics()
    assert m.served["a"] + m.served["b"] == 5
