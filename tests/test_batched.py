"""Batched multi-RHS engine: level-major + channel-folded paths, the fused
batched Pallas LP-step kernel, and the propagate_many serving path.

Parity chain pinned here (small N):

    batched mpt_matvec == stacked single-RHS mpt_matvec == dense Q @ Y
"""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.matvec import (collect_up, mpt_matvec, mpt_matvec_batched,
                               mpt_matvec_leaforder)
from repro.kernels.fused_lp import (fused_lp_matvec_batched,
                                    fused_lp_matvec_batched_ref,
                                    fused_lp_scan_batched,
                                    fused_lp_scan_batched_ref,
                                    fused_lp_step_batched,
                                    fused_lp_step_batched_ref)
from repro.serving import PropagateRequest, propagate_many


def _mv_args(vdt):
    return (vdt.tree, jnp.asarray(vdt.bp.a), jnp.asarray(vdt.bp.b),
            jnp.asarray(vdt.bp.active), vdt.qstate.log_q)


# --------------------------------------------------------- core batched path
@pytest.mark.parametrize("batch", [1, 3, 8])  # incl. non-power-of-two
def test_batched_matvec_matches_stacked_and_dense(small_fitted_vdt, batch):
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(batch)
    ys = r.randn(batch, n, 3).astype(np.float32)

    got = np.asarray(mpt_matvec_batched(*_mv_args(vdt), jnp.asarray(ys)))
    stacked = np.stack(
        [np.asarray(mpt_matvec(*_mv_args(vdt), jnp.asarray(ys[i])))
         for i in range(batch)])
    dense = vdt.dense_q()
    want = np.einsum("ij,bjc->bic", dense, ys)

    assert got.shape == (batch, n, 3)
    np.testing.assert_allclose(got, stacked, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_level_major_leaforder_accepts_leading_batch(small_fitted_vdt):
    """collect_up / mpt_matvec_leaforder carry batch dims natively."""
    _, vdt = small_fitted_vdt
    tree = vdt.tree
    r = np.random.RandomState(0)
    y_leaf = r.randn(4, tree.n_leaves, 2).astype(np.float32)
    y_leaf *= np.asarray(tree.w_leaf)[None, :, None]  # zero the ghosts

    t_b = np.asarray(collect_up(jnp.asarray(y_leaf), tree.L))
    t_s = np.stack([np.asarray(collect_up(jnp.asarray(y_leaf[i]), tree.L))
                    for i in range(4)])
    np.testing.assert_allclose(t_b, t_s, rtol=1e-6, atol=1e-6)

    q = jnp.where(jnp.asarray(vdt.bp.active) & jnp.isfinite(vdt.qstate.log_q),
                  jnp.exp(vdt.qstate.log_q), 0.0)
    a, b = jnp.asarray(vdt.bp.a), jnp.asarray(vdt.bp.b)
    o_b = np.asarray(mpt_matvec_leaforder(jnp.asarray(y_leaf), a, b, q, tree.L))
    o_s = np.stack(
        [np.asarray(mpt_matvec_leaforder(jnp.asarray(y_leaf[i]), a, b, q,
                                         tree.L)) for i in range(4)])
    np.testing.assert_allclose(o_b, o_s, rtol=1e-5, atol=1e-6)


def test_batched_matvec_rejects_bad_rank(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    with pytest.raises(ValueError):
        mpt_matvec_batched(*_mv_args(vdt), jnp.zeros((33, 2)))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_batched_linearity_property(small_fitted_vdt, seed):
    """Q(aY1 + Y2) == a QY1 + QY2 through the batched path (shape-stable
    draws: only the seed varies, so tier-1 pays one compile)."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(seed)
    y1 = jnp.asarray(r.randn(2, n, 2).astype(np.float32))
    y2 = jnp.asarray(r.randn(2, n, 2).astype(np.float32))
    o1 = np.asarray(mpt_matvec_batched(*_mv_args(vdt), y1))
    o2 = np.asarray(mpt_matvec_batched(*_mv_args(vdt), y2))
    o12 = np.asarray(mpt_matvec_batched(*_mv_args(vdt), 3.0 * y1 + y2))
    np.testing.assert_allclose(o12, 3.0 * o1 + o2, rtol=1e-3, atol=1e-4)


# ------------------------------------------------------ batched LP (eq. 15)
def test_batched_label_propagate_matches_loop(small_fitted_vdt):
    """(batch=8, N, C) stack == 8 looped single-RHS propagations (atol 1e-5,
    the PR's acceptance criterion)."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(1)
    y0 = (r.rand(8, n, 3) > 0.8).astype(np.float32)

    got = np.asarray(vdt.label_propagate(y0, alpha=0.05, n_iters=60))
    want = np.stack(
        [np.asarray(vdt.label_propagate(y0[i], alpha=0.05, n_iters=60))
         for i in range(8)])
    assert got.shape == (8, n, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_batched_label_propagate_batch_one(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(2)
    y0 = (r.rand(1, n, 2) > 0.8).astype(np.float32)
    got = np.asarray(vdt.label_propagate(y0, alpha=0.1, n_iters=40))
    want = np.asarray(vdt.label_propagate(y0[0], alpha=0.1, n_iters=40))
    np.testing.assert_allclose(got[0], want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------- fused batched Pallas kernel
@pytest.mark.parametrize("batch,n,c", [(1, 40, 2), (3, 33, 3), (4, 64, 1)])
def test_fused_batched_matvec_matches_ref(rng, batch, n, c):
    x = jnp.asarray(rng.randn(n, 5), jnp.float32)
    ys = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    got = fused_lp_matvec_batched(x, ys, 1.0, block_m=16, block_n=16)
    want = fused_lp_matvec_batched_ref(x, ys, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("batch", [1, 3])
def test_fused_batched_lp_step_matches_ref(rng, batch):
    n, c, alpha = 48, 2, 0.05
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    ys = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    y0s = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    got = fused_lp_step_batched(x, ys, y0s, 1.0, alpha, block_m=16, block_n=16)
    want = fused_lp_step_batched_ref(x, ys, y0s, 1.0, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_fused_batched_row_stochastic_action(rng):
    """P @ 1 == 1 for every batch element through the batched kernel."""
    x = jnp.asarray(rng.randn(40, 3), jnp.float32)
    ones = jnp.ones((3, 40, 1), jnp.float32)
    got = np.asarray(fused_lp_matvec_batched(x, ones, 1.0,
                                             block_m=16, block_n=16))
    np.testing.assert_allclose(got, 1.0, rtol=1e-5)


# ------------------------------------------ distance-reusing batched kernel
@pytest.mark.parametrize("batch", [1, 3, 8])
@pytest.mark.parametrize("c", [1, 2, 16])
@pytest.mark.parametrize("n", [37])  # odd, non-power-of-two: exercises padding
def test_reuse_kernel_matches_perbatch_and_dense(rng, batch, c, n):
    """The distance-reusing layout == the per-batch-recompute layout == the
    dense eq.-15 reference, across batch/width/ragged-N combinations."""
    alpha, sigma = 0.05, 1.0
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    ys = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    y0s = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    reuse = np.asarray(fused_lp_step_batched(
        x, ys, y0s, sigma, alpha, block_m=16, block_n=16, reuse=True))
    perbatch = np.asarray(fused_lp_step_batched(
        x, ys, y0s, sigma, alpha, block_m=16, block_n=16, reuse=False))
    dense = np.asarray(fused_lp_step_batched_ref(x, ys, y0s, sigma, alpha))
    np.testing.assert_allclose(reuse, perbatch, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(reuse, dense, rtol=1e-4, atol=1e-5)


def test_reuse_kernel_per_request_alpha(rng):
    """A traced (B,) alpha folds to per-column and matches the dense ref."""
    batch, n, c = 3, 40, 2
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    ys = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    y0s = jnp.asarray(rng.randn(batch, n, c), jnp.float32)
    al = jnp.asarray([0.01, 0.2, 1.0], jnp.float32)
    got = np.asarray(fused_lp_step_batched(x, ys, y0s, 1.0, al,
                                           block_m=16, block_n=16))
    want = (np.asarray(al)[:, None, None]
            * np.asarray(fused_lp_matvec_batched_ref(x, ys, 1.0))
            + (1.0 - np.asarray(al)[:, None, None]) * np.asarray(y0s))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_reuse_scan_matches_iterated_dense(rng):
    """The multi-iteration reuse scan == explicit dense eq.-15 iterations."""
    batch, n, c, iters = 2, 33, 3, 4
    x = jnp.asarray(rng.randn(n, 4), jnp.float32)
    y0s = jnp.asarray((rng.rand(batch, n, c) > 0.8), jnp.float32)
    al = jnp.asarray([0.05, 0.3], jnp.float32)
    got = np.asarray(fused_lp_scan_batched(x, y0s, 1.0, al, iters,
                                           block_m=16, block_n=16))
    want = np.asarray(fused_lp_scan_batched_ref(x, y0s, 1.0, al, iters))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------ exact serving backend
def test_label_propagate_exact_backend_matches_dense(small_fitted_vdt):
    """backend='exact' runs eq. 15 on the exact P (streamed, never dense) —
    parity with an explicit dense-P iteration at the fitted sigma."""
    from repro.core.baselines import exact_transition_matrix

    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(5)
    y0 = (r.rand(n, 3) > 0.8).astype(np.float32)
    got = np.asarray(vdt.label_propagate(y0, alpha=0.1, n_iters=6,
                                         backend="exact"))
    p = np.asarray(exact_transition_matrix(jnp.asarray(x), vdt.sigma))
    want = y0.copy()
    for _ in range(6):
        want = 0.1 * p @ want + 0.9 * y0
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    # batched with per-request alpha agrees with per-request exact calls
    y0s = (r.rand(2, n, 2) > 0.8).astype(np.float32)
    alphas = np.asarray([0.05, 0.2], np.float32)
    got_b = np.asarray(vdt.label_propagate(y0s, alpha=alphas, n_iters=6,
                                           backend="exact"))
    for b in range(2):
        want_b = np.asarray(vdt.label_propagate(
            y0s[b], alpha=float(alphas[b]), n_iters=6, backend="exact"))
        np.testing.assert_allclose(got_b[b], want_b, rtol=1e-5, atol=1e-5)


def test_label_propagate_rejects_unknown_backend(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    with pytest.raises(ValueError):
        vdt.label_propagate(np.zeros((33, 2), np.float32), backend="dense")


# ------------------------------------------------------------ serving layer
def test_propagate_many_matches_single_calls(small_fitted_vdt):
    """Heterogeneous widths/alphas, answered in request order, each equal to
    its single-RHS label_propagate."""
    x, vdt = small_fitted_vdt
    n = x.shape[0]
    r = np.random.RandomState(4)
    recipes = [(2, 0.05, 30), (3, 0.05, 30), (5, 0.05, 30), (2, 0.1, 30),
               (2, 0.05, 30)]
    reqs = [PropagateRequest((r.rand(n, c) > 0.8).astype(np.float32),
                             alpha=a, n_iters=it) for c, a, it in recipes]
    outs = propagate_many(vdt, reqs, max_batch=2)
    assert len(outs) == len(reqs)
    for req, out in zip(reqs, outs):
        assert out.shape == req.y0.shape
        want = np.asarray(vdt.label_propagate(
            jnp.asarray(req.y0), alpha=req.alpha, n_iters=req.n_iters))
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


def test_propagate_many_rejects_bad_shapes(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    with pytest.raises(ValueError):
        propagate_many(vdt, [PropagateRequest(np.zeros((5, 2), np.float32))])
    with pytest.raises(ValueError):
        propagate_many(
            vdt, [PropagateRequest(np.zeros((33, 999), np.float32))])
