"""Block-partition invariants: validity, coarsest structure, mirrors."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.blocks import (
    coarsest_partition,
    densify_q,
    mirror_invariant_ok,
    validate_partition,
)
from repro.core.qopt import optimize_q
from repro.core.refine import refine_to_budget
from repro.core.sigma import sigma_init
from repro.core.tree import build_tree


@pytest.mark.parametrize("n", [4, 7, 16, 33, 61])
def test_coarsest_partition_valid(rng, n):
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    assert validate_partition(bp, tree)
    assert mirror_invariant_ok(bp)


def test_coarsest_block_count_power_of_two(rng):
    """No ghosts: |B_c| = 2(Np - 1) exactly (paper §4.4)."""
    n = 32
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    assert bp.n_active == 2 * (n - 1)


def test_blocks_disjoint_sides(rng):
    x = rng.randn(24, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    from repro.core.tree import leaf_range

    for i in range(bp.n):
        if not bp.active[i]:
            continue
        la = leaf_range(int(bp.a[i]), tree.L)
        lb = leaf_range(int(bp.b[i]), tree.L)
        assert la[1] <= lb[0] or lb[1] <= la[0]  # A ∩ B = ∅


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=40),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_partition_validity_hypothesis(n, seed):
    r = np.random.RandomState(seed)
    x = r.randn(n, 4).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    assert validate_partition(bp, tree)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=24),
    budget_mult=st.integers(min_value=2, max_value=5),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_partition_stays_valid_under_refinement(n, budget_mult, seed):
    """Refinement must preserve exact single-coverage of all real pairs."""
    r = np.random.RandomState(seed)
    x = r.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree, cap=16 * n * n)
    sigma = sigma_init(x)
    refine_to_budget(bp, tree, sigma, max_blocks=budget_mult * bp.n_active, batch=7)
    assert validate_partition(bp, tree)


def test_densify_row_stochastic(rng):
    n = 19
    x = rng.randn(n, 3).astype(np.float32)
    tree = build_tree(x)
    bp = coarsest_partition(tree)
    qs = optimize_q(tree, jnp.asarray(bp.a), jnp.asarray(bp.b),
                    jnp.asarray(bp.active), jnp.asarray(1.0))
    q = np.where(np.isfinite(np.asarray(qs.log_q)), np.exp(np.asarray(qs.log_q)), 0.0)
    dense = densify_q(bp, tree, q)
    np.testing.assert_allclose(dense.sum(1), np.ones(n), rtol=1e-5)
    assert np.all(np.diagonal(dense) == 0)
