"""Public-API surface: snapshot pinning + deprecation shim contract."""
import importlib
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_api  # noqa: E402  (tools/ is not a package)

SHIMS = ("repro.serving.engine", "repro.serving.propagate",
         "repro.serving.queue", "repro.serving.metrics")


def test_public_api_matches_snapshot():
    """The committed snapshot equals the live surface — any intentional
    API change must regenerate tests/api_snapshot.json in the same PR."""
    expected = json.loads(check_api.SNAPSHOT.read_text())
    actual = check_api.describe_surface()
    problems = check_api.diff_surfaces(expected, actual)
    assert not problems, (
        "public API drifted from tests/api_snapshot.json; if intentional, "
        "run `python tools/check_api.py --update` and commit:\n"
        + "\n".join(problems))


def test_check_api_cli_green():
    """The CI entry point itself exits 0 against the committed snapshot."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_api.py")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_every_public_name_importable_from_package():
    import repro.serving as pkg

    for name in pkg.__all__:
        assert getattr(pkg, name) is not None


@pytest.mark.parametrize("module", SHIMS)
def test_deep_module_shims_warn_but_work(module):
    """Historical deep imports still resolve — through a DeprecationWarning
    — and hand back the SAME objects the package exports."""
    sys.modules.pop(module, None)  # force the import-time warning to re-fire
    with pytest.warns(DeprecationWarning, match="deprecated"):
        shim = importlib.import_module(module)
    pkg = importlib.import_module("repro.serving")
    for name in shim.__all__:
        shim_obj = getattr(shim, name)
        pkg_obj = getattr(pkg, name, None)
        if pkg_obj is not None:  # public names must be identical objects
            assert shim_obj is pkg_obj, (module, name)


def test_shim_objects_are_canonical():
    """No duplicated classes: a PropagateEngine from the old path IS the
    class from the new path (isinstance checks keep working across the
    migration)."""
    for module in SHIMS:
        sys.modules.pop(module, None)
    with pytest.warns(DeprecationWarning):
        from repro.serving.engine import PropagateEngine as old_engine
    from repro.serving import PropagateEngine as new_engine

    assert old_engine is new_engine
