"""Public-API surface: snapshot pinning + deprecation shim contract."""
import importlib
import json
import pathlib
import subprocess
import sys
import warnings

import pytest

from repro.serving import _deprecation

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_api  # noqa: E402  (tools/ is not a package)

SHIMS = ("repro.serving.engine", "repro.serving.propagate",
         "repro.serving.queue", "repro.serving.metrics",
         "repro.serving.decode")


def _reimport(module, *, reset_ledger):
    """Fresh import of a shim, optionally resetting its warn-once ledger."""
    sys.modules.pop(module, None)
    if reset_ledger:
        _deprecation._WARNED.discard(module)
    return importlib.import_module(module)


def test_public_api_matches_snapshot():
    """The committed snapshot equals the live surface — any intentional
    API change must regenerate tests/api_snapshot.json in the same PR."""
    expected = json.loads(check_api.SNAPSHOT.read_text())
    actual = check_api.describe_surface()
    problems = check_api.diff_surfaces(expected, actual)
    assert not problems, (
        "public API drifted from tests/api_snapshot.json; if intentional, "
        "run `python tools/check_api.py --update` and commit:\n"
        + "\n".join(problems))


def test_check_api_cli_green():
    """The CI entry point itself exits 0 against the committed snapshot."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_api.py")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_every_public_name_importable_from_package():
    import repro.serving as pkg

    for name in pkg.__all__:
        assert getattr(pkg, name) is not None


@pytest.mark.parametrize("module", SHIMS)
def test_deep_module_shims_warn_but_work(module):
    """Historical deep imports still resolve — through a DeprecationWarning
    — and hand back the SAME objects the package exports."""
    with pytest.warns(DeprecationWarning, match="deprecated"):
        shim = _reimport(module, reset_ledger=True)
    pkg = importlib.import_module("repro.serving")
    for name in shim.__all__:
        shim_obj = getattr(shim, name)
        pkg_obj = getattr(pkg, name, None)
        if pkg_obj is not None:  # public names must be identical objects
            assert shim_obj is pkg_obj, (module, name)


@pytest.mark.parametrize("module", SHIMS)
def test_shim_warns_exactly_once_per_process(module):
    """The warn-once ledger: a shim's DeprecationWarning fires on the first
    import of the process and NEVER again — even if the module is evicted
    from sys.modules and re-imported — until the ledger is reset."""
    with pytest.warns(DeprecationWarning, match="deprecated"):
        _reimport(module, reset_ledger=True)
    # second import with the ledger intact must be silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _reimport(module, reset_ledger=False)


def test_shim_objects_are_canonical():
    """No duplicated classes: a PropagateEngine from the old path IS the
    class from the new path (isinstance checks keep working across the
    migration)."""
    sys.modules.pop("repro.serving.engine", None)
    _deprecation._WARNED.discard("repro.serving.engine")
    with pytest.warns(DeprecationWarning):
        from repro.serving.engine import PropagateEngine as old_engine
    from repro.serving import PropagateEngine as new_engine

    assert old_engine is new_engine


def test_blessed_surface_imports_warning_free():
    """`import repro.serving` — the ONLY blessed serving import path — must
    raise no DeprecationWarning in a fresh interpreter.  The shims warn;
    the package does not."""
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "import repro.serving"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
