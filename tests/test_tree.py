"""Partition-tree invariants: heap layout, weighted statistics, ghosts."""
import numpy as np
import pytest
from _hyp import given, settings, st


from repro.core.tree import build_tree, leaf_range, level_slice, node_level


def _stats_ok(x, tree):
    n = x.shape[0]
    # root statistics equal global statistics
    assert np.isclose(float(tree.W[0]), n)
    np.testing.assert_allclose(np.asarray(tree.S1[0]), x.sum(0), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        float(tree.S2[0]), (x * x).sum(), rtol=1e-4, atol=1e-3
    )
    # every internal node's stats are the sum of its children's
    W = np.asarray(tree.W)
    S1 = np.asarray(tree.S1)
    S2 = np.asarray(tree.S2)
    for k in range(tree.n_internal):
        assert np.isclose(W[k], W[2 * k + 1] + W[2 * k + 2])
        np.testing.assert_allclose(S1[k], S1[2 * k + 1] + S1[2 * k + 2],
                                   rtol=1e-4, atol=1e-3)
        assert np.isclose(S2[k], S2[2 * k + 1] + S2[2 * k + 2], rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,d", [(8, 2), (37, 5), (64, 3), (100, 7), (3, 1)])
def test_tree_stats_consistency(rng, n, d):
    x = rng.randn(n, d).astype(np.float32)
    tree = build_tree(x)
    _stats_ok(x, tree)


@pytest.mark.parametrize("n", [5, 8, 13, 64, 100])
def test_leaf_permutation_bijection(rng, n):
    x = rng.randn(n, 4).astype(np.float32)
    tree = build_tree(x)
    slot_of = np.asarray(tree.slot_of)
    leaf_of = np.asarray(tree.leaf_of)
    # every real row maps to a unique slot and back
    assert len(set(slot_of.tolist())) == n
    for i in range(n):
        assert leaf_of[slot_of[i]] == i
    # ghost slots carry zero weight and zero coordinates
    w = np.asarray(tree.w_leaf)
    ghosts = np.setdiff1d(np.arange(tree.n_leaves), slot_of)
    assert np.all(w[ghosts] == 0)
    assert np.all(w[slot_of] == 1)


def test_points_in_leaf_order_match(rng):
    x = rng.randn(21, 3).astype(np.float32)
    tree = build_tree(x)
    slot_of = np.asarray(tree.slot_of)
    xl = np.asarray(tree.x_leaf)
    np.testing.assert_allclose(xl[slot_of], x, rtol=1e-6)


def test_leaf_range_contiguity():
    L = 4
    lo, hi = leaf_range(0, L)
    assert (lo, hi) == (0, 16)
    lo, hi = leaf_range(1, L)
    assert (lo, hi) == (0, 8)
    lo, hi = leaf_range(2, L)
    assert (lo, hi) == (8, 16)
    # a node's range is the union of its children's
    for k in range(7):
        l1 = leaf_range(2 * k + 1, L)
        l2 = leaf_range(2 * k + 2, L)
        assert leaf_range(k, L) == (l1[0], l2[1])
        assert l1[1] == l2[0]


def test_node_level_and_slices():
    assert node_level(0) == 0
    assert node_level(1) == 1 and node_level(2) == 1
    assert node_level(3) == 2
    assert level_slice(0) == slice(0, 1)
    assert level_slice(2) == slice(3, 7)


def test_split_quality_separated_clusters(rng):
    """The root split should separate two far-apart clusters."""
    a = rng.randn(16, 3).astype(np.float32) + 50.0
    b = rng.randn(16, 3).astype(np.float32) - 50.0
    x = np.concatenate([a, b])
    tree = build_tree(x)
    left_rows = set(np.asarray(tree.leaf_of)[: tree.n_leaves // 2].tolist())
    # all of one cluster on one side
    assert left_rows in (set(range(16)), set(range(16, 32)))


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=70),
    d=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tree_properties_hypothesis(n, d, seed):
    r = np.random.RandomState(seed)
    x = (r.randn(n, d) * r.uniform(0.1, 10)).astype(np.float32)
    tree = build_tree(x)
    _stats_ok(x, tree)
    # weights: exactly n real leaves
    assert int(np.asarray(tree.w_leaf).sum()) == n


def test_weighted_build(rng):
    x = rng.randn(20, 3).astype(np.float32)
    w = (rng.rand(20) > 0.3).astype(np.float32)
    tree = build_tree(x, weights=w)
    assert np.isclose(float(tree.W[0]), w.sum())
    np.testing.assert_allclose(
        np.asarray(tree.S1[0]), (x * w[:, None]).sum(0), rtol=1e-4, atol=1e-3
    )


def test_duplicate_points(rng):
    """Degenerate data (all identical) must still build a valid tree."""
    x = np.ones((10, 4), dtype=np.float32)
    tree = build_tree(x)
    assert float(tree.W[0]) == 10
    assert not np.any(np.isnan(np.asarray(tree.S1)))
