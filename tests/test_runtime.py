"""Runtime substrate: checkpoint atomicity/roundtrip/elasticity, preemption,
watchdog, gradient compression."""
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed.compression import (compress_tree, decompress_tree)
from repro.runtime import checkpoint as ckpt
from repro.runtime.preemption import GracefulShutdown, Watchdog

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(r.randn(4, 8), jnp.float32),
        "nested": {"b": jnp.asarray(r.randn(3), jnp.float32),
                   "c": jnp.asarray(r.randint(0, 5, (2, 2)), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 7, t, fingerprint="fp1")
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), t)
    restored, step = ckpt.restore(tmp_path, like, expect_fingerprint="fp1")
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        t, restored)


def test_checkpoint_latest_pointer(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    ckpt.save(tmp_path, 5, t)
    ckpt.save(tmp_path, 3, t)  # out-of-order write: LATEST moves to 3
    assert ckpt.latest_step(tmp_path) == 3


def test_checkpoint_fingerprint_mismatch_refuses(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t, fingerprint="good")
    with pytest.raises(ValueError, match="fingerprint"):
        ckpt.restore(tmp_path, t, expect_fingerprint="bad")


def test_checkpoint_structure_mismatch_refuses(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"only": jnp.zeros(3)})


def test_checkpoint_async_then_wait(tmp_path):
    t = _tree(3)
    ckpt.save_async(tmp_path, 11, t, fingerprint="x")
    ckpt.wait_for_saves()
    restored, step = ckpt.restore(tmp_path, t)
    assert step == 11


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    """A completed save leaves no tmp dirs behind."""
    ckpt.save(tmp_path, 2, _tree())
    leftovers = [p for p in tmp_path.iterdir() if ".tmp" in p.name]
    assert not leftovers


def test_elastic_restore_across_device_counts(tmp_path):
    """Save on an 8-device mesh, restore onto 4, then back onto 8.

    Runs in subprocesses because XLA fixes the device count per process.
    """
    script = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        sys.path.insert(0, %r)
        from repro.runtime import checkpoint as ckpt
        mesh = jax.make_mesh((%d,), ("data",))
        t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sh = {"w": NamedSharding(mesh, P("data", None))}
        if %r == "save":
            t = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, s), t, sh)
            ckpt.save(%r, 1, t, fingerprint="elastic")
        else:
            restored, step = ckpt.restore(%r, t, shardings=sh,
                                          expect_fingerprint="elastic")
            w = restored["w"]
            assert len(w.sharding.device_set) == %d, w.sharding
            np.testing.assert_array_equal(np.asarray(w),
                np.arange(64, dtype=np.float32).reshape(8, 8))
        print("OK")
    """)

    def run(n_dev, mode):
        code = script % (n_dev, SRC, n_dev, mode, str(tmp_path),
                         str(tmp_path), n_dev)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=240)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    run(8, "save")
    run(4, "load")   # elastic: fewer devices
    run(8, "load")   # elastic: back to more devices


def test_graceful_shutdown_flag():
    g = GracefulShutdown(signals=())
    assert not g.requested
    g.request()
    assert g.requested


def test_watchdog_detects_stall():
    events = []
    w = Watchdog(timeout_s=0.2, on_stall=lambda dt: events.append(dt),
                 poll_s=0.02).start()
    for _ in range(3):
        w.beat()
        time.sleep(0.05)
    assert not w.stalled
    time.sleep(0.4)
    assert w.stalled and events
    w.stop()


# ------------------------------------------------------- grad compression
def test_bf16_compression_bound(rng):
    g = {"w": jnp.asarray(rng.randn(128, 64), jnp.float32)}
    c, aux = compress_tree(g, "bf16")
    d = decompress_tree(c, aux, "bf16")
    rel = np.abs(np.asarray(d["w"]) - np.asarray(g["w"])) / (
        np.abs(np.asarray(g["w"])) + 1e-9)
    assert rel.max() < 1e-2
    assert c["w"].dtype == jnp.bfloat16


def test_int8_compression_unbiased(rng):
    """Stochastic rounding: E[deq(q(g))] == g (bias shrinks with n trials)."""
    g = {"w": jnp.asarray(rng.randn(32, 16), jnp.float32)}
    acc = np.zeros((32, 16), np.float64)
    trials = 200
    for i in range(trials):
        c, aux = compress_tree(g, "int8", key=jax.random.PRNGKey(i))
        acc += np.asarray(decompress_tree(c, aux, "int8")["w"])
    mean = acc / trials
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    bias = np.abs(mean - np.asarray(g["w"]))
    assert bias.max() < 4 * scale / np.sqrt(trials) + 1e-6


def test_int8_compression_error_bound(rng):
    g = {"w": jnp.asarray(rng.randn(64, 64), jnp.float32)}
    c, aux = compress_tree(g, "int8", key=jax.random.PRNGKey(0))
    d = decompress_tree(c, aux, "int8")
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    err = np.abs(np.asarray(d["w"]) - np.asarray(g["w"]))
    assert err.max() <= scale + 1e-6
    assert c["w"].dtype == jnp.int8
