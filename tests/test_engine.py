"""Continuous-batching engine: deterministic-scheduler parity, cancellation,
backpressure, metrics, and the propagate_many alpha-canonicalization fix.

The deterministic tests drive the scheduler synchronously (``start=False`` +
``step``/``flush``) so every assertion is race-free; one threaded test and
the slow soak exercise the background-thread path end to end.
"""
import threading

import numpy as np
import pytest

from repro.serving import (PropagateEngine, PropagateRequest, QueueFull,
                           propagate_many)
from repro.serving._batching import canonical_alpha, group_key

ITERS = 8  # plenty for parity, cheap enough for tier-1


def _random_requests(rng, n, count, widths=(1, 2, 3, 4, 6),
                     alphas=(0.01, 0.05, 0.2), iters=(ITERS,)):
    reqs = []
    for _ in range(count):
        c = int(rng.choice(widths))
        y0 = (rng.rand(n, c) > 0.8).astype(np.float32)
        reqs.append(PropagateRequest(
            y0, alpha=float(rng.choice(alphas)),
            n_iters=int(rng.choice(iters))))
    return reqs


# ------------------------------------------------------------ parity chain
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_engine_matches_propagate_many_and_single(small_fitted_vdt, seed):
    """engine == propagate_many == per-request label_propagate, any arrival
    order / width mix / alpha mix."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(seed)
    reqs = _random_requests(rng, x.shape[0], count=11)

    eng = PropagateEngine(vdt, start=False, max_batch=4)
    futs = [eng.submit(q) for q in reqs]
    eng.flush()
    got = [np.asarray(f.result(timeout=0)) for f in futs]

    via_many = propagate_many(vdt, reqs)
    for g, m, req in zip(got, via_many, reqs):
        assert g.shape == req.y0.shape
        np.testing.assert_allclose(g, np.asarray(m), rtol=1e-5, atol=1e-6)
        single = vdt.label_propagate(req.y0, alpha=req.alpha,
                                     n_iters=req.n_iters)
        np.testing.assert_allclose(g, np.asarray(single),
                                   rtol=1e-5, atol=1e-6)


def test_engine_mixed_n_iters_and_submit_order(small_fitted_vdt):
    """Requests differing only in n_iters never share a dispatch but still
    come back right, whatever order they were submitted in."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(3)
    reqs = _random_requests(rng, x.shape[0], count=8, iters=(4, ITERS))
    order = rng.permutation(len(reqs))

    eng = PropagateEngine(vdt, start=False, max_batch=8)
    futs = {i: eng.submit(reqs[i]) for i in order}
    eng.flush()
    for i, req in enumerate(reqs):
        single = vdt.label_propagate(req.y0, alpha=req.alpha,
                                     n_iters=req.n_iters)
        np.testing.assert_allclose(np.asarray(futs[i].result(timeout=0)),
                                   np.asarray(single), rtol=1e-5, atol=1e-6)


def test_engine_exact_backend_matches_single(small_fitted_vdt):
    """backend='exact' coalesces a mixed group through the distance-reusing
    fused kernel; each answer equals its single exact label_propagate."""
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(9)
    reqs = _random_requests(rng, x.shape[0], count=6, widths=(1, 2, 3))

    eng = PropagateEngine(vdt, start=False, max_batch=4, backend="exact")
    futs = [eng.submit(q) for q in reqs]
    eng.flush()
    for f, req in zip(futs, reqs):
        single = vdt.label_propagate(req.y0, alpha=req.alpha,
                                     n_iters=req.n_iters, backend="exact")
        np.testing.assert_allclose(np.asarray(f.result(timeout=0)),
                                   np.asarray(single), rtol=1e-5, atol=1e-5)
    eng.shutdown()


def test_engine_rejects_unknown_backend(small_fitted_vdt):
    _, vdt = small_fitted_vdt
    with pytest.raises(ValueError):
        PropagateEngine(vdt, start=False, backend="dense")


def test_engine_threaded_end_to_end(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(4)
    reqs = _random_requests(rng, x.shape[0], count=12)
    want = propagate_many(vdt, reqs)

    with PropagateEngine(vdt, max_batch=4, max_wait_ms=1.0) as eng:
        futs = [eng.submit(q) for q in reqs]
        for f, w in zip(futs, want):
            np.testing.assert_allclose(np.asarray(f.result(timeout=60)),
                                       np.asarray(w), rtol=1e-5, atol=1e-6)


# --------------------------------------------------- cancellation / errors
def test_cancellation_before_dispatch(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(5)
    reqs = _random_requests(rng, x.shape[0], count=4, widths=(2,))

    eng = PropagateEngine(vdt, start=False)
    futs = [eng.submit(q) for q in reqs]
    assert futs[1].cancel() and futs[2].cancel()
    eng.flush()

    assert futs[1].cancelled() and futs[2].cancelled()
    for i in (0, 3):
        single = vdt.label_propagate(reqs[i].y0, alpha=reqs[i].alpha,
                                     n_iters=reqs[i].n_iters)
        np.testing.assert_allclose(np.asarray(futs[i].result(timeout=0)),
                                   np.asarray(single), rtol=1e-5, atol=1e-6)
    m = eng.metrics()
    assert m.cancelled == 2 and m.completed == 2
    assert m.batched_requests == 2  # cancelled entries never hit a dispatch


def test_submit_rejects_bad_shapes(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, start=False)
    with pytest.raises(ValueError):
        eng.submit(PropagateRequest(np.zeros((x.shape[0] + 1, 2), np.float32)))
    with pytest.raises(ValueError):  # wider than the largest bucket
        eng.submit(PropagateRequest(np.zeros((x.shape[0], 129), np.float32)))
    assert eng.metrics().submitted == 0


# ------------------------------------------------------------- backpressure
def test_backpressure_bounded_queue(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 2), np.float32)
    eng = PropagateEngine(vdt, start=False, max_queue=2)
    eng.submit(PropagateRequest(y0, n_iters=2), block=False)
    eng.submit(PropagateRequest(y0, n_iters=2), block=False)
    with pytest.raises(QueueFull):
        eng.submit(PropagateRequest(y0, n_iters=2), block=False)
    with pytest.raises(QueueFull):  # blocking submit with a timeout
        eng.submit(PropagateRequest(y0, n_iters=2), timeout=0.01)
    m = eng.metrics()
    assert m.rejected == 2 and m.queue_depth == 2

    eng.step()  # drain frees capacity; submits flow again
    eng.submit(PropagateRequest(y0, n_iters=2), block=False)
    eng.flush()
    assert eng.metrics().completed == 3


def test_blocked_submit_unblocks_on_drain(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    y0 = np.zeros((x.shape[0], 1), np.float32)
    eng = PropagateEngine(vdt, start=False, max_queue=1)
    eng.submit(PropagateRequest(y0, n_iters=2), block=False)

    accepted = threading.Event()

    def blocked_producer():
        eng.submit(PropagateRequest(y0, n_iters=2), timeout=30)
        accepted.set()

    t = threading.Thread(target=blocked_producer, daemon=True)
    t.start()
    assert not accepted.wait(0.05)  # genuinely blocked on the full queue
    eng.step()
    assert accepted.wait(30)
    t.join()
    eng.flush()
    assert eng.metrics().completed == 2


# ------------------------------------------------------------------ metrics
def test_metrics_snapshot_counters(small_fitted_vdt):
    x, vdt = small_fitted_vdt
    rng = np.random.RandomState(6)
    reqs = _random_requests(rng, x.shape[0], count=6, widths=(2, 3))

    eng = PropagateEngine(vdt, start=False, max_batch=8)
    for q in reqs:
        eng.submit(q)
    assert eng.metrics().queue_depth == 6
    eng.flush()
    m = eng.metrics()
    assert m.submitted == m.completed == 6
    assert m.queue_depth == 0 and m.in_flight == 0
    # widths 2 and 3 both land in buckets <= 4 -> at most 2 dispatch groups
    assert 1 <= m.dispatches <= 2
    assert m.batch_occupancy >= 3.0
    assert m.latency_p50_ms > 0 and m.latency_p95_ms >= m.latency_p50_ms


# ------------------------------------------------- shutdown/flush contracts
class _FakeClock:
    """Deterministic time source for deadline-sensitive lifecycle tests."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def test_submit_shutdown_race_cancels_orphan(small_fitted_vdt):
    """An entry landing during the final flush (put succeeded, then
    shutdown won the race) must come back cancelled + RuntimeError — never
    as a future nobody will ever resolve."""
    x, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, start=False)
    real_put = eng._queue.put

    def racing_put(entry, **kw):
        real_put(entry, **kw)
        eng._closed = True  # shutdown wins the race right after the put

    eng._queue.put = racing_put
    fut_holder = []
    with pytest.raises(RuntimeError, match="shut down"):
        eng.submit(PropagateRequest(
            y0=np.zeros((x.shape[0], 1), np.float32)))
    assert eng.metrics().cancelled == 1
    assert eng.metrics().submitted == 0
    assert not fut_holder  # nothing escaped to a caller


@pytest.mark.parametrize("wait", [True, False])
def test_shutdown_resolves_expired_with_deadline_exceeded(
        small_fitted_vdt, wait):
    """Both shutdown paths honor the pinned DeadlineExceeded contract for
    entries that expired while queued: ``wait=False`` must not degrade
    them into a bare ``cancel()``."""
    from repro.serving import DeadlineExceeded

    x, vdt = small_fitted_vdt
    clock = _FakeClock()
    eng = PropagateEngine(vdt, start=False, policy="edf", clock=clock)
    y0 = np.zeros((x.shape[0], 1), np.float32)
    doomed = eng.submit(PropagateRequest(y0=y0, n_iters=2, deadline_ms=10.0))
    live = eng.submit(PropagateRequest(y0=y0, n_iters=2))
    clock.advance(1.0)  # the deadlined entry expires while queued

    eng.shutdown(wait=wait)
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=0)
    m = eng.metrics()
    assert m.expired == 1
    if wait:
        assert live.result(timeout=0) is not None
        assert m.completed == 1 and m.cancelled == 0
    else:
        assert live.cancelled()
        assert m.completed == 0 and m.cancelled == 1


def test_flush_drains_snapshot_under_concurrent_producers(small_fitted_vdt):
    """flush() serves the backlog present at call time and terminates even
    when producers keep pace with service — the old ``while len(queue)``
    loop would livelock (or here: drain the producer's traffic forever)."""
    x, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, start=False, max_batch=1)
    y0 = np.zeros((x.shape[0], 1), np.float32)
    backlog = [eng.submit(PropagateRequest(y0=y0, n_iters=2))
               for _ in range(3)]

    extra = []
    real_step = eng.step

    def feeding_step():
        n = real_step()
        # a concurrent producer lands one request per service round
        extra.append(eng.submit(PropagateRequest(y0=y0, n_iters=2)))
        return n

    eng.step = feeding_step
    resolved = eng.flush()
    assert resolved == 3  # exactly the snapshot backlog
    assert all(f.done() for f in backlog)
    assert len(extra) == 3 and not any(f.done() for f in extra)
    assert len(eng._queue) == 3  # racing traffic waits for the next pass
    eng.step = real_step
    eng.shutdown()  # serves the stragglers
    assert all(f.done() for f in extra)


def test_scheduler_internal_error_counted_and_survived(
        small_fitted_vdt, caplog):
    """A scheduler-internal fault must not kill the loop silently: it is
    counted (scheduler_errors), its traceback logged, and the next
    iteration serves traffic normally."""
    x, vdt = small_fitted_vdt
    eng = PropagateEngine(vdt, max_wait_ms=0)
    fired = threading.Event()
    real_step = eng.step

    def bad_step():
        if not fired.is_set():
            fired.set()
            raise RuntimeError("injected scheduler fault")
        return real_step()

    eng.step = bad_step
    with caplog.at_level("ERROR", logger="repro.serving._engine"):
        fut = eng.submit(PropagateRequest(
            y0=np.zeros((x.shape[0], 1), np.float32), n_iters=2))
        assert fut.result(timeout=60) is not None
    assert eng.metrics().scheduler_errors >= 1
    assert "scheduler iteration failed" in caplog.text
    assert "injected scheduler fault" in caplog.text  # full traceback, not a swallow
    eng.shutdown()


# --------------------------------------- propagate_many alpha fragmentation
def test_alpha_canonicalization_regression(small_fitted_vdt, monkeypatch):
    """Near-equal alphas (0.01 vs 0.010000001) must share one dispatch —
    the raw float(req.alpha) group key used to fragment them."""
    x, vdt = small_fitted_vdt
    assert canonical_alpha(0.01) == canonical_alpha(0.010000001)
    assert group_key(0.01, 5, 2, (2, 4)) == group_key(0.010000001, 5, 2, (2, 4))
    assert canonical_alpha(0.01) != canonical_alpha(0.02)

    rng = np.random.RandomState(7)
    y0 = (rng.rand(x.shape[0], 2) > 0.8).astype(np.float32)
    reqs = [PropagateRequest(y0, alpha=0.01, n_iters=ITERS),
            PropagateRequest(y0, alpha=0.010000001, n_iters=ITERS)]

    calls = []
    real_lp = vdt.label_propagate

    def counting_lp(y0, *a, **kw):
        if np.asarray(y0).ndim == 3:  # count dispatches, not the inner fold
            calls.append(y0)
        return real_lp(y0, *a, **kw)

    monkeypatch.setattr(vdt, "label_propagate", counting_lp)
    out = propagate_many(vdt, reqs)
    assert len(calls) == 1, "near-equal alphas fragmented into dispatches"
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]),
                               rtol=1e-6, atol=1e-7)


# ------------------------------------------------------ divergence isolation
@pytest.fixture(scope="module")
def positive_data_vdts():
    """Two models over the SAME strictly-positive data, different divergences."""
    from repro.core.vdt import VariationalDualTree

    r = np.random.RandomState(11)
    x = (r.rand(33, 4).astype(np.float32) + 0.1)
    vdt_sq = VariationalDualTree.fit(x, max_blocks=4 * 33)
    vdt_kl = VariationalDualTree.fit(x, max_blocks=4 * 33, divergence="kl")
    return x, vdt_sq, vdt_kl


def test_engines_with_different_divergences_stay_isolated(positive_data_vdts):
    """Two engines fitted with different divergences over the same data must
    return different, per-divergence-correct LP answers and report separate
    compile-cache dispatch keys in the metrics snapshot — mixed-divergence
    deployments can never cross-contaminate the compile cache."""
    from repro.kernels.fused_lp import fused_lp_scan_batched_ref

    x, vdt_sq, vdt_kl = positive_data_vdts
    assert vdt_sq.divergence_name == "sqeuclidean"
    assert vdt_kl.divergence_name == "kl"

    rng = np.random.RandomState(12)
    y0 = (rng.rand(x.shape[0], 2) > 0.7).astype(np.float32)
    reqs = [PropagateRequest(y0, alpha=0.2, n_iters=4),
            PropagateRequest(y0 * 0.5, alpha=0.1, n_iters=4)]

    # the exact backend keys its fused kernels statically on the divergence,
    # so this exercises the actual compiled-executable isolation
    eng_sq = PropagateEngine(vdt_sq, start=False, backend="exact")
    eng_kl = PropagateEngine(vdt_kl, start=False, backend="exact")
    futs_sq = [eng_sq.submit(q) for q in reqs]
    futs_kl = [eng_kl.submit(q) for q in reqs]
    eng_sq.flush()
    eng_kl.flush()

    for fut_sq, fut_kl, req in zip(futs_sq, futs_kl, reqs):
        got_sq = np.asarray(fut_sq.result(timeout=0))
        got_kl = np.asarray(fut_kl.result(timeout=0))
        # per-divergence correctness against the dense eq.-15 oracle
        want_sq = np.asarray(fused_lp_scan_batched_ref(
            x, req.y0[None], float(vdt_sq.sigma), req.alpha, req.n_iters))[0]
        want_kl = np.asarray(fused_lp_scan_batched_ref(
            x, req.y0[None], float(vdt_kl.sigma), req.alpha, req.n_iters,
            divergence="kl"))[0]
        np.testing.assert_allclose(got_sq, want_sq, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got_kl, want_kl, rtol=1e-5, atol=1e-5)
        # ... and the two divergences genuinely disagree on the same input
        assert np.abs(got_sq - got_kl).max() > 1e-4

    # separate compile-cache keys in the metrics snapshot
    m_sq, m_kl = eng_sq.metrics(), eng_kl.metrics()
    assert m_sq.dispatch_key == "exact:sqeuclidean"
    assert m_kl.dispatch_key == "exact:kl"
    assert m_sq.dispatch_key != m_kl.dispatch_key
    assert m_sq.completed == m_kl.completed == len(reqs)


def test_vdt_backend_engines_divergence_keys(positive_data_vdts):
    """The default-backend engines expose the divergence in their dispatch
    key too (their q already encodes it as data)."""
    _, vdt_sq, vdt_kl = positive_data_vdts
    eng_sq = PropagateEngine(vdt_sq, start=False)
    eng_kl = PropagateEngine(vdt_kl, start=False)
    assert eng_sq.metrics().dispatch_key == "vdt:sqeuclidean"
    assert eng_kl.metrics().dispatch_key == "vdt:kl"


# --------------------------------------------------------- epoch isolation
@pytest.fixture(scope="module")
def streamed_pair(small_fitted_vdt):
    """(old model, streaming-updated model) with DIFFERENT point counts.

    The changed N makes epoch mixing loud: an old-epoch entry dispatched
    against the new tree (or vice versa) is a shape error, not a silent
    numerical drift.
    """
    x, vdt = small_fitted_vdt
    r = np.random.RandomState(31)
    upd = vdt.delete_points([2, 7, 11])
    upd = upd.vdt.insert_points(r.randn(5, x.shape[1]).astype(np.float32))
    return vdt, upd.vdt, upd


def _width2_requests(rng, n, count, alphas=(0.01, 0.2)):
    return [PropagateRequest((rng.rand(n, 2) > 0.8).astype(np.float32),
                             alpha=float(rng.choice(alphas)), n_iters=ITERS)
            for _ in range(count)]


def test_midflight_publish_preserves_old_epoch_bits(streamed_pair):
    """The publish atomicity contract, bit-for-bit.

    Entries queued before a publish must resolve EXACTLY as they would on
    an engine that never saw the publish; entries submitted after it must
    resolve exactly as on an engine fitted with the new model from the
    start.  Deterministic scheduler (start=False + flush), so the dispatch
    grouping is identical across the control and test engines.
    """
    vdt0, vdt1, upd = streamed_pair
    n0, n1 = vdt0.tree.n_points, vdt1.tree.n_points
    assert n0 != n1
    reqs_old = _width2_requests(np.random.RandomState(41), n0, 7)
    reqs_new = _width2_requests(np.random.RandomState(42), n1, 7)

    control_old = PropagateEngine(vdt0, start=False, max_batch=4)
    want_old = [control_old.submit(q) for q in reqs_old]
    control_old.flush()
    want_old = [np.asarray(f.result(timeout=0)) for f in want_old]

    control_new = PropagateEngine(vdt1, start=False, max_batch=4)
    want_new = [control_new.submit(q) for q in reqs_new]
    control_new.flush()
    want_new = [np.asarray(f.result(timeout=0)) for f in want_new]

    eng = PropagateEngine(vdt0, start=False, max_batch=4)
    futs_old = [eng.submit(q) for q in reqs_old]  # queued on epoch 0
    eid = eng.publish(vdt1, patched_points=upd.patched_points,
                      stale_blocks=upd.stale_blocks)
    assert eid == 1
    m = eng.metrics()
    assert m.epoch == 1 and m.epochs_published == 1
    assert m.live_epochs == 2  # epoch 0 still pinned by the queued entries
    assert m.patched_points == upd.patched_points
    assert m.stale_blocks == upd.stale_blocks
    futs_new = [eng.submit(q) for q in reqs_new]  # land on epoch 1
    eng.flush()

    for f, w in zip(futs_old, want_old):
        assert np.array_equal(np.asarray(f.result(timeout=0)), w)
    for f, w in zip(futs_new, want_new):
        assert np.array_equal(np.asarray(f.result(timeout=0)), w)

    eng.step()  # retirement already happened; this prunes stale staging
    m = eng.metrics()
    assert m.live_epochs == 1 and m.epochs_retired == 1
    assert all(key[0] == n1 for key in eng.dispatch_state.staging)
    eng.shutdown()


def test_midflight_publish_grf_pinned_epoch_bits(streamed_pair):
    """Epoch isolation holds for the stochastic backend too: grf entries
    queued before a publish resolve bit-identically to an engine that
    never saw the publish.  This is stronger than the deterministic
    backends' version — the walk set depends on the graph (cached per
    model instance) AND the engine's grf_seed, so any epoch mixing would
    change the sampled paths, not just drift numerics."""
    vdt0, vdt1, upd = streamed_pair
    n0, n1 = vdt0.tree.n_points, vdt1.tree.n_points

    def grf_reqs(seed, n):
        rng = np.random.RandomState(seed)
        return [PropagateRequest((rng.rand(n, 2) > 0.8).astype(np.float32),
                                 alpha=float(rng.choice((0.01, 0.2))),
                                 n_iters=6, backend="grf")
                for _ in range(5)]

    reqs_old, reqs_new = grf_reqs(51, n0), grf_reqs(52, n1)
    kw = dict(start=False, max_batch=4, n_walkers=8, grf_seed=7)

    control_old = PropagateEngine(vdt0, **kw)
    want_old = [control_old.submit(q) for q in reqs_old]
    control_old.flush()
    want_old = [np.asarray(f.result(timeout=0)) for f in want_old]

    control_new = PropagateEngine(vdt1, **kw)
    want_new = [control_new.submit(q) for q in reqs_new]
    control_new.flush()
    want_new = [np.asarray(f.result(timeout=0)) for f in want_new]

    eng = PropagateEngine(vdt0, **kw)
    futs_old = [eng.submit(q) for q in reqs_old]  # pinned to epoch 0
    eng.publish(vdt1, patched_points=upd.patched_points,
                stale_blocks=upd.stale_blocks)
    futs_new = [eng.submit(q) for q in reqs_new]  # land on epoch 1
    eng.flush()

    for f, w in zip(futs_old, want_old):
        assert np.array_equal(np.asarray(f.result(timeout=0)), w)
    for f, w in zip(futs_new, want_new):
        assert np.array_equal(np.asarray(f.result(timeout=0)), w)
    m = eng.metrics()
    assert m.live_epochs == 1 and m.epochs_retired == 1
    assert m.n_walkers == 8
    eng.shutdown()
    control_old.shutdown()
    control_new.shutdown()


def test_publish_switches_submit_validation(streamed_pair):
    """Submits racing a publish validate against the epoch they land on."""
    vdt0, vdt1, _ = streamed_pair
    n0, n1 = vdt0.tree.n_points, vdt1.tree.n_points
    eng = PropagateEngine(vdt0, start=False)
    eng.publish(vdt1)
    with pytest.raises(ValueError):  # old-N shape no longer valid
        eng.submit(PropagateRequest(np.zeros((n0, 2), np.float32)))
    fut = eng.submit(PropagateRequest(np.zeros((n1, 2), np.float32),
                                      n_iters=2))
    eng.flush()
    assert fut.result(timeout=0).shape == (n1, 2)
    m = eng.metrics()
    assert m.submitted == 1 and m.completed == 1
    eng.shutdown()


def test_epoch_pins_released_without_dispatch(streamed_pair):
    """Cancellation and EDF expiry release an old epoch's pins too — an
    epoch must never stay alive because its entries died off-dispatch."""
    vdt0, vdt1, _ = streamed_pair
    n0 = vdt0.tree.n_points
    clock = _FakeClock()
    eng = PropagateEngine(vdt0, start=False, policy="edf", clock=clock)
    y0 = np.zeros((n0, 2), np.float32)
    doomed = eng.submit(PropagateRequest(y0, n_iters=2, deadline_ms=10.0))
    dropped = eng.submit(PropagateRequest(y0, n_iters=2))
    eng.publish(vdt1)
    assert eng.metrics().live_epochs == 2
    assert dropped.cancel()
    clock.advance(1.0)  # expires `doomed` while queued
    eng.step()
    m = eng.metrics()
    assert m.expired == 1 and m.cancelled == 1
    assert m.live_epochs == 1 and m.epochs_retired == 1
    eng.shutdown()


def test_publish_lifecycle_errors(streamed_pair):
    vdt0, vdt1, _ = streamed_pair
    eng = PropagateEngine(vdt0, start=False)
    eng.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        eng.publish(vdt1)


def test_engine_base_publish_is_optional_capability():
    """Engines that don't override publish() advertise that loudly."""
    from repro.serving.engine_api import Engine

    class Minimal(Engine):
        fit_params = dispatch_state = None

        def submit(self, request, *, block=True, timeout=None): ...
        def warmup(self, widths=None, n_iters=(500,), backends=None): ...
        def step(self): ...
        def flush(self): ...
        def metrics(self): ...
        def shutdown(self, wait=True): ...

    with pytest.raises(NotImplementedError, match="epoch publishing"):
        Minimal().publish(object())


# --------------------------------------------------------------------- soak
@pytest.mark.slow
def test_engine_soak_threaded(separated_clusters_vdt):
    """Nightly soak: many closed-loop clients, mixed widths/alphas/iters,
    every answer checked against the single-request path."""
    x, _, vdt = separated_clusters_vdt
    n = x.shape[0]
    n_clients, per_client = 8, 12
    errs = []

    with PropagateEngine(vdt, max_batch=16, max_wait_ms=1.0,
                         max_queue=64) as eng:
        def client(cid):
            rng = np.random.RandomState(100 + cid)
            try:
                for _ in range(per_client):
                    req = _random_requests(rng, n, 1, iters=(4, 8))[0]
                    got = np.asarray(eng.submit(req).result(timeout=120))
                    want = np.asarray(vdt.label_propagate(
                        req.y0, alpha=req.alpha, n_iters=req.n_iters))
                    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
            except Exception as exc:  # surface in the main thread
                errs.append(exc)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        m = eng.metrics()

    assert not errs, errs[:1]
    assert m.completed == n_clients * per_client
    assert m.failed == 0
    # continuous batching must actually batch under concurrent load
    assert m.batch_occupancy > 1.5
