"""Documentation checker: keep README.md / docs/*.md honest in CI.

Three checks over every tracked markdown file (README.md and docs/*.md):

1. **syntax** — every fenced ``python`` code block must ``compile()``;
   pseudo-code must be explicitly opted out with a marker (below).
2. **run** — blocks annotated with an HTML comment marker directly above
   the fence are executed in a subprocess with ``PYTHONPATH=src`` and a
   timeout, so the README quickstart keeps running as-is on a clean
   checkout::

       <!-- docs-check: run -->
       ```python
       ...executed by CI...
       ```

   ``<!-- docs-check: skip -->`` exempts a block from all checks
   (illustrative fragments).
3. **links** — every intra-repo markdown link ``[text](path)`` must point
   at an existing file (resolved relative to the markdown file; ``#anchor``
   suffixes stripped; ``http(s):``/``mailto:`` links ignored).

Usage::

    python tools/check_docs.py [--no-run] [--timeout SECONDS]

Exits non-zero listing every failure.  ``tests/test_docs.py`` runs the same
checks in tier-1 so breakage surfaces locally before CI.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

_MARKER_RE = re.compile(r"^\s*<!--\s*docs-check:\s*(\w+)\s*-->\s*$")
# tolerant of info strings ("```python title=x"): anything after the
# language word is ignored, so a fancier fence can't invert code/prose
_FENCE_RE = re.compile(r"^```\s*([\w.+-]*)")
_LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")


def doc_files(root: Path = REPO_ROOT) -> list[Path]:
    """The markdown files under contract: README.md plus docs/*.md."""
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("*.md")))
    return files


def extract_blocks(text: str) -> list[tuple[int, str, str, str]]:
    """Fenced code blocks as ``(lineno, lang, tag, code)`` tuples.

    ``tag`` is the ``docs-check:`` marker immediately above the fence
    (``"run"``, ``"skip"``) or ``""`` when absent.
    """
    blocks = []
    lines = text.splitlines()
    pending = ""
    i = 0
    while i < len(lines):
        marker = _MARKER_RE.match(lines[i])
        if marker:
            pending = marker.group(1)
            i += 1
            continue
        fence = _FENCE_RE.match(lines[i])
        if fence:
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            blocks.append((start + 1, fence.group(1) or "", pending,
                           "\n".join(lines[start:j])))
            pending = ""
            i = j + 1
            continue
        if lines[i].strip():
            pending = ""  # a marker only binds to the very next fence
        i += 1
    return blocks


def check_code_blocks(path: Path, *, run: bool = True,
                      timeout: float = 240.0) -> list[str]:
    """Syntax-check python blocks; execute ``docs-check: run`` blocks."""
    failures = []
    for lineno, lang, tag, code in extract_blocks(path.read_text()):
        if tag == "skip" or lang not in ("python", "py"):
            continue
        where = f"{path.relative_to(REPO_ROOT)}:{lineno}"
        try:
            compile(code, where, "exec")
        except SyntaxError as exc:
            failures.append(f"{where}: python block does not compile: {exc}")
            continue
        if tag == "run" and run:
            env = dict(os.environ)
            env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
            try:
                proc = subprocess.run(
                    [sys.executable, "-"], input=code, text=True,
                    capture_output=True, timeout=timeout, env=env,
                    cwd=REPO_ROOT)
            except subprocess.TimeoutExpired:
                failures.append(f"{where}: run block timed out after {timeout}s")
                continue
            if proc.returncode != 0:
                tail = proc.stderr.strip().splitlines()[-8:]
                failures.append(f"{where}: run block failed "
                                f"(exit {proc.returncode}):\n  "
                                + "\n  ".join(tail))
    return failures


def check_links(path: Path) -> list[str]:
    """Every intra-repo link target must exist on disk."""
    failures = []
    text = path.read_text()
    # don't validate links that only occur inside code fences
    for _, _, _, code in extract_blocks(text):
        text = text.replace(code, "")
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            failures.append(
                f"{path.relative_to(REPO_ROOT)}: broken link -> {target}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-run", action="store_true",
                    help="syntax/link checks only; skip executing run blocks")
    ap.add_argument("--timeout", type=float, default=240.0,
                    help="per-block execution timeout (seconds)")
    args = ap.parse_args()

    files = doc_files()
    if not files:
        print("no documentation files found", file=sys.stderr)
        return 1
    failures = []
    for path in files:
        failures += check_code_blocks(path, run=not args.no_run,
                                      timeout=args.timeout)
        failures += check_links(path)
    if failures:
        print("docs-check FAILED:", file=sys.stderr)
        for failure in failures:
            print(f"  - {failure}", file=sys.stderr)
        return 1
    print(f"docs-check passed ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
