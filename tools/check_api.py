"""Public-API snapshot checker: keep ``repro.serving`` changes deliberate.

The serving tier's public surface — every name in
``repro.serving.__all__``, its kind, and its callable signature(s) — is
snapshotted into ``tests/api_snapshot.json``.  CI re-derives the surface
from the live package and diffs it against the committed snapshot, so the
blessed API can only change together with an explicit snapshot update in
the same PR (an intentional, reviewable event) — never as a silent side
effect of a refactor.

What is snapshotted per exported name:

* its **kind** (``class`` / ``function`` / ``exception`` / ``constant``);
* for functions: the full signature;
* for classes: the ``__init__`` signature plus every public method's
  signature and every public non-callable attribute (dataclass fields,
  properties);
* for constants: the repr of the value.

Usage::

    python tools/check_api.py            # verify against the snapshot
    python tools/check_api.py --update   # rewrite the snapshot (intentional
                                         # API changes; commit the diff)

Exits non-zero listing every added / removed / changed name.
``tests/test_api_surface.py`` runs the same check in tier-1 so drift
surfaces locally before CI.
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / "tests" / "api_snapshot.json"


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"


def _describe_class(cls) -> dict:
    methods: dict[str, str] = {}
    attributes: list[str] = []
    for name, member in sorted(vars(cls).items()):
        if name.startswith("_") and name != "__init__":
            continue
        if isinstance(member, property):
            attributes.append(name)
        elif callable(member) or isinstance(member, (staticmethod, classmethod)):
            fn = member.__func__ if isinstance(
                member, (staticmethod, classmethod)) else member
            methods[name] = _signature(fn)
        else:
            attributes.append(name)
    # dataclass fields are part of the contract even when they only exist
    # as annotations (frozen dataclasses with defaults)
    for name in getattr(cls, "__dataclass_fields__", {}):
        if not name.startswith("_") and name not in attributes:
            attributes.append(name)
    return {
        "kind": "exception" if issubclass(cls, BaseException) else "class",
        "init": methods.pop("__init__", _signature(cls.__init__)),
        "methods": methods,
        "attributes": sorted(attributes),
    }


def describe_surface() -> dict:
    """Derive the live public surface of ``repro.serving``."""
    import repro.serving as pkg

    surface: dict[str, dict] = {}
    for name in sorted(pkg.__all__):
        obj = getattr(pkg, name)
        if inspect.isclass(obj):
            surface[name] = _describe_class(obj)
        elif callable(obj):
            surface[name] = {"kind": "function", "signature": _signature(obj)}
        else:
            surface[name] = {"kind": "constant", "value": repr(obj)}
    return {"module": "repro.serving", "surface": surface}


def diff_surfaces(expected: dict, actual: dict) -> list[str]:
    """Human-readable drift list; empty when the surfaces match.

    Every line names the symbol WITH its kind (``class`` / ``function`` /
    ``exception`` / ``constant``): "removed: QueueFull (exception)" tells
    a reviewer what broke without opening the snapshot, and a kind
    transition (a constant becoming a function, say) is reported as such
    rather than as an opaque JSON mismatch.
    """
    problems: list[str] = []
    exp, act = expected.get("surface", {}), actual.get("surface", {})
    for name in sorted(set(exp) | set(act)):
        if name not in act:
            kind = exp[name].get("kind", "?")
            problems.append(f"removed from public API: {name} ({kind})")
        elif name not in exp:
            kind = act[name].get("kind", "?")
            problems.append(
                f"added to public API without snapshot: {name} ({kind})")
        elif exp[name] != act[name]:
            ekind = exp[name].get("kind", "?")
            akind = act[name].get("kind", "?")
            kind = (ekind if ekind == akind
                    else f"kind changed: {ekind} -> {akind}")
            problems.append(
                f"changed: {name} ({kind})"
                f"\n  snapshot: {json.dumps(exp[name], sort_keys=True)}"
                f"\n  live:     {json.dumps(act[name], sort_keys=True)}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="rewrite the snapshot from the live surface")
    args = ap.parse_args(argv)

    actual = describe_surface()
    if args.update:
        SNAPSHOT.write_text(json.dumps(actual, indent=2, sort_keys=True) + "\n")
        print(f"wrote {SNAPSHOT} ({len(actual['surface'])} names)")
        return 0

    if not SNAPSHOT.exists():
        print(f"missing snapshot {SNAPSHOT}; run with --update and commit it")
        return 1
    expected = json.loads(SNAPSHOT.read_text())
    problems = diff_surfaces(expected, actual)
    if problems:
        print(f"public API drift vs {SNAPSHOT.name} "
              f"(intentional? rerun with --update and commit):")
        for p in problems:
            print(f"- {p}")
        return 1
    print(f"public API matches snapshot ({len(actual['surface'])} names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
